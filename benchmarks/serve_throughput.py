"""Serving throughput sweep: tokens/s AND latency tails under continuous
batching, over slots x prompt-length mix x ABFT scheme x cache kind
(ROADMAP open item, paper §6 deployment scenario).

For each cell the engine serves a fixed request set end to end and we
report wall-clock tokens/s, p50/p95/p99 TTFT and inter-token-latency
percentiles (every generated token is wall-clock stamped by the engine),
plus ``cache_stats()`` — the paged cells size their pool to the traffic's
peak *working set* (not slots × max_len), so a skewed prompt mix shows
the paged cache allocating a fraction of the dense bytes while producing
the identical greedy token streams.

The ``templated`` mix models system-prompt traffic: every request opens
with the same template and differs only in a short tail.  Its cells add
a ``paged_shared`` engine (refcounted prefix sharing + copy-on-write):
streams must stay byte-identical to dense AND unshared-paged while the
per-step mean ``blocks_used`` drops ≥2x (the shared template is resident
ONCE, chained through overlapping sharers, instead of once per slot).

The ``long_prompt`` mix exposes the admission stall: mostly-short
traffic with rare near-max-length prompts.  Unchunked engines prefill a
long prompt in ONE model call on the decode path, so every resident
stream's inter-token gap spikes — visible as the p99 ITL.  The
``paged_chunked`` cells (chunked-prefill scheduler, ``chunk_tokens``
step budget) bound the co-scheduled prefill work per step; the
acceptance metric is ``chunked_itl_p99_frac`` (chunked p99 ITL over the
admit-time-prefill baseline) at equal throughput with byte-identical
streams.  Chunked cells also report the per-step intensity-guided
``selection`` summary (mixed vs decode-only step compositions and the
schemes the selector picked for them).

The ``chunked_auto`` cell (long_prompt mix, intensity_guided scheme)
exercises ``ServeEngine(chunk_tokens="auto")``: the step budget comes
from ``ProtectionPlan.tune_chunk_budget`` — the smallest budget whose
mixed-step arithmetic intensity clears the device CMR — instead of a
flag.  Its acceptance keys: ``auto_matches_dense`` (byte-identical
streams), ``auto_clears_cmr`` (the tuned budget's intensity vs the CMR),
and ``auto_tput_frac`` (auto throughput over the best FIXED budget from
the half/default/double ``fixed_budget_sweep``).

Every cell reports the fixed occupancy accounting — ``utilization``
against allocated tokens, ``fragmentation``, ``blocks_shared``,
``prefix_hit_rate`` — plus the ``rejections`` / ``evictions`` split.

The ``spec_decode`` sweep serves periodic prompts (the prompt-lookup
best case) through speculative engines: draft length K x proposer x
ABFT scheme, each against an unsped baseline of the SAME engine
geometry.  Acceptance keys per row: ``spec_matches_dense`` (greedy
streams byte-identical to the unsped run — speculation is an execution
strategy, not an approximation), ``accept_rate``, and
``spec_tput_frac``.  The intensity-guided rows run under
``AUTO_TUNE_HW``, crafted so plain decode (slots tokens) sits below the
CMR while a full K=4 verify window (slots x 5 tokens) clears it: the
sweep's ``verify_schemes`` show the per-step selector flipping
``block_1s`` -> ``global`` as K grows, with the matching
``scheme_flips`` counts.  The ``tuned`` row runs ``draft_len="auto"``
(``ProtectionPlan.tune_draft_len`` picks K from the roofline + the
acceptance-rate prior); its gate is ``tuned_beats_fixed_median`` —
tuned-K throughput at least the median of the fixed-K rows under the
same scheme.

``--mesh 1,2,4`` adds a sharded sweep: bf16 params + paged KV sharded
over a (data=1, model=N) device mesh per width, each engine compiling
its protection plan from the POST-sharding per-device GEMM shapes
(``SHARD_SWEEP_HW`` is crafted so the selector lands on different
schemes per width).  Each row reports tokens/s, the per-shard scheme
table, and ``matches_mesh1`` — greedy streams must stay byte-identical
to the width-1 baseline.

  PYTHONPATH=src python benchmarks/serve_throughput.py \
      [--quick] [--out results.json] [--slots 2,4] [--new-tokens 8] \
      [--mixes uniform_short,long_prompt] [--chunk-tokens 16]

Wall-clock numbers are CPU-measured (this container); they order schemes
by redundant-work cost, not by TPU speed — see benchmarks/common.py.
"""

from __future__ import annotations

import argparse
import collections
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, scaled_down
from repro.core import ABFTConfig, FixedPolicy, Scheme, compute_bound_ai
from repro.core.hardware import HardwareSpec
from repro.models import build_model
from repro.obs import EngineTelemetry
from repro.serve.engine import EngineStats, Request, ServeEngine
from repro.serve.paged_cache import blocks_for

SCHEMES = {
    # none: protection off; traditional: one global checksum for every
    # layer (Hari et al.); guided: the paper's intensity-guided selector
    "none": ABFTConfig.off(),
    "traditional": ABFTConfig.from_policy(
        FixedPolicy(Scheme.GLOBAL), use_pallas=False),
    "intensity_guided": ABFTConfig(scheme=Scheme.AUTO, use_pallas=False),
}

# Hardware for the --mesh sweep's per-shard plans: CMR=24 sits between
# the smoke model's full-width mlp/lm_head intensities (25.6/28.4) and
# every 4-way shard's (<=21.3), and the slow-VPU/cheap-fixed-op balance
# makes global ABFT's dispatch cost amortize only over the full-width
# GEMMs — so the width sweep shows the selector flipping scheme per
# shard (tests/test_sharded_engine.py asserts the same divergence)
SHARD_SWEEP_HW = HardwareSpec(
    name="shard-flip", peak_flops=2.4e13, vpu_flops=1e11, hbm_bw=1e12,
    ici_bw=1e11, hbm_bytes=1 << 34, vmem_bytes=1 << 24,
    fixed_op_overhead_s=1e-7)

# Hardware for the chunked_auto cell's budget autotuning AND the
# spec_decode sweep: a CMR the benchmark's scaled step geometry (k=64,
# n=128, f32) can actually clear, so tune_chunk_budget has a real
# roofline crossing to find instead of saturating at the max_len cap
# (the real v5e CMR of ~241 is unreachable for a 64-wide d_model —
# crafted specs are how the selection tests exercise the crossover
# too).  Same ratios as the FLIP_HW test spec.  The step-composition
# crossover sits at 18 tokens: plain decode at 4 slots (4 tokens) is
# memory-bound -> block_1s, a K=4 verify window (4 x 5 = 20 tokens)
# clears the CMR -> global — the spec sweep's scheme-flip evidence.
AUTO_TUNE_HW = HardwareSpec(
    name="bench-flip", peak_flops=1e10, vpu_flops=2.6e8, hbm_bw=1e9,
    ici_bw=1e9, hbm_bytes=1 << 30, vmem_bytes=1 << 20,
    fixed_op_overhead_s=1e-6)

MIXES = {
    # (length, weight) pairs; lengths are fractions of max_len
    "uniform_short": [(0.15, 1.0)],
    "skewed": [(0.08, 3.0), (0.75, 1.0)],   # mostly short + one long tail
    # mostly short with periodic near-max prompts arriving mid-flight:
    # the admission-stall / chunked-prefill showcase (the long prefill
    # is what spikes resident streams' p99 ITL)
    "long_prompt": "long_prompt",
    # system-prompt traffic: shared template + short unique tail (the
    # prefix-sharing best case; worst case for unshared paging)
    "templated": "templated",
}

# template length as a fraction of max_len; 0.75 keeps the default
# geometry block-aligned (48 tokens = 3 x 16-token blocks), so sharers
# alias whole template blocks and own only their tail/decode block
TEMPLATE_FRAC = 0.75


def _requests(mix, n: int, max_len: int, new_tokens: int) -> tuple:
    rng = np.random.default_rng(0)
    if mix == "long_prompt":
        # deterministic arrival pattern: short prompts with LONG decode
        # budgets get resident first, then every 4th request is a
        # near-max prompt whose admission (or chunk stream) lands while
        # they are still decoding — the staggered budgets guarantee the
        # overlap that makes the admission stall visible in their
        # inter-token gaps
        short = max(2, int(0.04 * max_len))
        long = max(short + 1, int(0.88 * max_len))
        reqs, lens = [], []
        for i in range(n):
            if i % 4 == 2:
                L, budget = long, new_tokens
            else:
                L, budget = short, 3 * new_tokens + i % 3
            reqs.append(Request(
                uid=i, prompt=(1 + np.arange(L, dtype=np.int32) % 250),
                max_new_tokens=budget))
            lens.append(L)
        return reqs, lens
    if mix == "templated":
        # one fixed template, per-request tails of 1-4 tokens, and
        # staggered decode budgets — overlap is what lets later requests
        # share the template blocks a live sharer keeps resident
        tpl_len = max(2, int(TEMPLATE_FRAC * max_len))
        template = 1 + np.arange(tpl_len, dtype=np.int32) % 250
        reqs, lens = [], []
        for i in range(n):
            tail = 1 + (50 + 13 * i + np.arange(1 + i % 4,
                                                dtype=np.int32)) % 250
            prompt = np.concatenate([template, tail])
            budget = max(2, new_tokens - 2 + (i * 3) % 5)
            reqs.append(Request(uid=i, prompt=prompt,
                                max_new_tokens=budget))
            lens.append(len(prompt))
        return reqs, lens
    fracs, weights = zip(*mix)
    w = np.asarray(weights) / sum(weights)
    lens = [int(max(2, rng.choice(fracs, p=w) * max_len)) for _ in range(n)]
    return [
        Request(uid=i, prompt=(1 + np.arange(L, dtype=np.int32) % 250),
                max_new_tokens=new_tokens)
        for i, L in enumerate(lens)
    ], lens


def _spec_requests(n: int, max_len: int, new_tokens: int) -> list:
    """Periodic prompts for the spec_decode sweep: the trailing n-gram
    recurs throughout the prompt, so the prompt-lookup proposer finds
    continuations and acceptance stays high — the traffic regime
    speculative decoding is built for (greedy equality must hold for
    ANY acceptance rate; the tests cover the adversarial end)."""
    reqs = []
    for i in range(n):
        pat = 3 + np.arange(4 + i % 2, dtype=np.int32)
        L = max(8, int(0.4 * max_len)) + i % 3
        reqs.append(Request(
            uid=i, prompt=np.tile(pat, max_len)[:L],
            max_new_tokens=new_tokens))
    return reqs


def _pool_blocks(lens, slots, new_tokens, block_size) -> int:
    """Blocks covering the peak per-slot working set of this traffic:
    the ``slots`` largest requests resident at once, each grown to
    prompt + decode budget."""
    need = sorted((blocks_for(L + new_tokens, block_size) for L in lens),
                  reverse=True)
    return max(1, sum(need[:slots]))


def _percentiles_ms(samples) -> dict:
    """p50/p95/p99 of a latency sample list, in milliseconds."""
    if not samples:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0}
    xs = np.asarray(samples, np.float64) * 1e3
    return {"p50": float(np.percentile(xs, 50)),
            "p95": float(np.percentile(xs, 95)),
            "p99": float(np.percentile(xs, 99))}


def _latency_stats(reqs, t0: float) -> dict:
    """TTFT (first stamped token minus batch-arrival t0) and pooled
    inter-token gaps, from the engine's per-token wall-clock stamps."""
    ttft = [r.times[0] - t0 for r in reqs if r.times]
    itl = [b - a for r in reqs for a, b in zip(r.times, r.times[1:])]
    return {"ttft_ms": _percentiles_ms(ttft), "itl_ms": _percentiles_ms(itl)}


def _selection_summary(stats: EngineStats) -> dict:
    """Condense the per-step (intensity, scheme) trace: how often the
    step composition was mixed vs decode-only, the mean intensity of
    each, and which schemes the selector picked."""
    tr = stats.selection_trace
    mixed = [e["intensity"] for e in tr if e["decode"] and e["prefill"]]
    dec = [e["intensity"] for e in tr if e["decode"] and not e["prefill"]]
    return {
        "mixed_steps": stats.mixed_steps,
        "decode_only_steps": stats.decode_only_steps,
        "prefill_only_steps": stats.prefill_only_steps,
        "intensity_mixed_mean": float(np.mean(mixed)) if mixed else 0.0,
        "intensity_decode_mean": float(np.mean(dec)) if dec else 0.0,
        "schemes": dict(collections.Counter(e["scheme"] for e in tr)),
    }


def run_cell(model, params, reqs, *, slots, max_len, abft, cache_kind,
             num_blocks=None, block_size=16,
             prefix_sharing=False, chunk_tokens=None, mesh=None,
             spec_decode=None, draft_len=None,
             dtype=jnp.float32,
             telemetry: EngineTelemetry | None = None) -> dict:
    eng = ServeEngine(
        model, params, slots=slots, max_len=max_len, abft=abft,
        dtype=dtype, cache_kind=cache_kind, block_size=block_size,
        num_blocks=num_blocks, prefix_sharing=prefix_sharing,
        chunk_tokens=chunk_tokens, mesh=mesh,
        spec_decode=spec_decode, draft_len=draft_len)
    # warm-up pass: serve a throwaway copy of the same traffic so jit
    # compilation (which dominates cold wall time on CPU) is excluded
    # from the reported tokens/s; shapes repeat, so the timed run below
    # hits the compile cache
    eng.run([Request(uid=r.uid, prompt=r.prompt,
                     max_new_tokens=r.max_new_tokens) for r in reqs])
    if eng.pool is not None:
        eng.pool.reset()            # warm-up must not seed the shared run
    if eng.index is not None:
        from repro.serve.paged_cache import PrefixIndex

        eng.index = PrefixIndex(block_size)
    eng.stats = EngineStats()
    if telemetry is not None:
        # attach AFTER the warm-up + stats reset: the mirrored counters
        # are monotonic and must start from the fresh EngineStats (the
        # timed run is also the only one worth exporting)
        eng.attach_telemetry(telemetry)
    t0 = time.perf_counter()
    eng.run([r for r in reqs])
    dt = time.perf_counter() - t0
    if telemetry is not None:
        for r in reqs:
            if r.times:
                telemetry.observe_ttft(r.times[0] - t0)
            for a, b in zip(r.times, r.times[1:]):
                telemetry.observe_itl(b - a)
    stats = eng.cache_stats()
    cell = {
        "tokens": eng.stats.tokens,
        "tokens_per_s": eng.stats.tokens / dt,
        "wall_s": dt,
        "errors": sum(1 for r in reqs if r.error),
        "rejections": eng.stats.rejections,
        "evictions": eng.stats.evictions,
        "cache_bytes": stats["bytes_total"],
        "tokens_capacity": stats["tokens_capacity"],
        "utilization": stats["utilization"],
        "fragmentation": stats["fragmentation"],
        "blocks_shared": stats["blocks_shared"],
        "prefix_hit_rate": stats["prefix_hit_rate"],
        "blocks_used_mean": eng.stats.blocks_used_mean,
        "blocks_used_median": eng.stats.blocks_used_median,
        "blocks_used_peak": eng.stats.blocks_used_peak,
        "blocks_shared_peak": eng.stats.blocks_shared_peak,
        "cow_copies": eng.stats.cow_copies,
        "prefill_chunks": eng.stats.prefill_chunks,
        "selection": _selection_summary(eng.stats),
        "streams": {r.uid: r.generated for r in reqs},
    }
    if mesh is not None:
        # the per-shard protection plan: compiled from POST-sharding
        # per-device GEMM shapes, so a width sweep shows the
        # intensity-guided selection re-deciding as TP narrows the GEMMs
        cell["model_parallel"] = eng.model_parallel
        cell["shard_plan"] = [
            {"layer": r["layer"], "scheme": r["scheme"],
             "ai": r["ai"], "bound": r["bound"]}
            for r in eng.plan.report_rows()]
    if chunk_tokens is not None:
        # the EFFECTIVE budget (chunk_tokens="auto" resolves it via the
        # plan's roofline autotuner and may re-tune mid-run) plus the
        # intensity evidence behind it and the plan's modeled step
        # throughput (wall clock on this CPU container is dispatch-
        # dominated; the model is the device-relevant ordering)
        cell["chunk_budget"] = eng.chunk_tokens
        cell["budget_retunes"] = eng.stats.chunk_budget_retunes
        cell["mixed_step_intensity"] = eng.plan.step_intensity(
            eng.chunk_tokens)
        cell["cmr"] = eng.plan.hardware.cmr
        cell["modeled_step_tput"] = (
            eng.chunk_tokens / eng.plan.modeled_step_time(eng.chunk_tokens))
    if spec_decode is not None:
        # the speculative accounting the acceptance criteria key on:
        # draft economics + which schemes the per-step selector picked
        # for the K-scaled verify windows
        prop = eng.stats.draft_proposed
        cell["spec"] = {
            "proposer": eng.spec.name,
            "draft_len": eng.draft_len,
            "draft_proposed": prop,
            "draft_accepted": eng.stats.draft_accepted,
            "accept_rate": eng.stats.draft_accepted / max(prop, 1),
            "verify_retries": eng.stats.verify_retries,
            "scheme_flips": eng.stats.scheme_flips,
            # schemes of the decode-composition steps only (the verify
            # windows); prefill steps are compute-bound on any hardware
            # and would mask the K-driven crossover
            "schemes": dict(collections.Counter(
                e["scheme"] for e in eng.stats.selection_trace
                if e["decode"] and not e["prefill"])),
        }
    cell.update(_latency_stats(reqs, t0))
    if telemetry is not None:
        cell["telemetry"] = dict(
            telemetry.snapshot(),
            counters_match_stats=telemetry.counters_match(eng.stats),
            trace_events=list(telemetry.tracer.events))
    return cell


def _spec_sweep(model, params, args) -> dict:
    """The speculative-decoding sweep: draft length K x proposer x ABFT
    scheme over periodic-prompt traffic, each row judged against an
    unsped baseline of the same engine geometry.  Runs at 4 slots
    regardless of ``--slots``: the AUTO_TUNE_HW crossover sits at 18
    step tokens, so 4-slot plain decode (4 tokens) stays memory-bound
    while a full K=4 verify window (20 tokens) clears the CMR — the
    scheme-flip evidence the sweep exists to produce."""
    slots = 4
    ks = [1, 4] if args.quick else [1, 2, 4]
    proposers = ["ngram"] if args.quick else ["ngram", "self_draft"]
    # decode budgets long enough that the steady state (full-K windows
    # once the proposer locks on) dominates the first-step ramp-in
    new_toks = max(args.new_tokens, 16)
    reqs_proto = _spec_requests(args.requests, args.max_len, new_toks)
    lens = [len(r.prompt) for r in reqs_proto]
    # a verify step grows each slot's KV by up to K+1 tokens before the
    # acceptance cursor settles; size the pool with tuned-K headroom
    nb = _pool_blocks(lens, slots, new_toks + 9, args.block_size)
    schemes = {
        "none": ABFTConfig.off(),
        "intensity_guided": ABFTConfig(
            scheme=Scheme.AUTO, use_pallas=False, hardware=AUTO_TUNE_HW),
    }

    def cell(**kw):
        reqs = [Request(uid=r.uid, prompt=r.prompt,
                        max_new_tokens=r.max_new_tokens)
                for r in reqs_proto]
        return run_cell(model, params, reqs, slots=slots,
                        max_len=args.max_len, cache_kind="paged",
                        num_blocks=nb, block_size=args.block_size, **kw)

    rows = []
    base_tput, base_streams, decode_scheme = {}, {}, None
    for scheme_name, abft in schemes.items():
        base = cell(abft=abft)
        base_streams[scheme_name] = base.pop("streams")
        base_tput[scheme_name] = base["tokens_per_s"]
        if scheme_name == "intensity_guided":
            sel = base["selection"]["schemes"]
            decode_scheme = max(sel, key=sel.get) if sel else None
        for prop in proposers:
            for k in ks:
                c = cell(abft=abft, spec_decode=prop, draft_len=k)
                streams = c.pop("streams")
                row = dict(
                    c, scheme=scheme_name, proposer=prop, draft_len=k,
                    spec_matches_dense=(
                        streams == base_streams[scheme_name]),
                    spec_tput_frac=(c["tokens_per_s"]
                                    / max(base_tput[scheme_name], 1e-9)))
                rows.append(row)
                print(f"spec  scheme={scheme_name:16s} "
                      f"proposer={prop:10s} K={k} "
                      f"accept={row['spec']['accept_rate']:.2f} "
                      f"tput={row['spec_tput_frac']:.2f}x "
                      f"match={row['spec_matches_dense']} "
                      f"schemes={row['spec']['schemes']}")

    # tuned row: draft_len="auto" resolves K via the plan's roofline +
    # acceptance-rate prior (ProtectionPlan.tune_draft_len); acceptance
    # is throughput at least the median of the fixed-K rows under the
    # same scheme and proposer
    tuned_c = cell(abft=schemes["intensity_guided"], spec_decode="ngram",
                   draft_len="auto")
    t_streams = tuned_c.pop("streams")
    tuned = dict(
        tuned_c, scheme="intensity_guided", proposer="ngram",
        draft_len="auto",
        tuned_draft_len=tuned_c["spec"]["draft_len"],
        spec_matches_dense=(
            t_streams == base_streams["intensity_guided"]),
        spec_tput_frac=(tuned_c["tokens_per_s"]
                        / max(base_tput["intensity_guided"], 1e-9)))
    fixed = [r["tokens_per_s"] for r in rows
             if r["scheme"] == "intensity_guided"
             and r["proposer"] == "ngram"]
    median = float(np.median(fixed)) if fixed else 0.0
    verify_schemes = {
        str(r["draft_len"]): r["spec"]["schemes"]
        for r in rows if r["scheme"] == "intensity_guided"
        and r["proposer"] == "ngram"}
    # the scheme-flip evidence: some K's verify windows cross the CMR
    # and land on a scheme plain decode never selects
    flipped = decode_scheme is not None and any(
        s != decode_scheme
        for v in verify_schemes.values() for s in v)
    out = {
        "hardware": AUTO_TUNE_HW.name, "slots": slots,
        "draft_lens": ks, "proposers": proposers,
        "baseline_tokens_per_s": base_tput,
        "rows": rows, "tuned": tuned,
        "tuned_draft_len": tuned["tuned_draft_len"],
        "fixed_tput_median": median,
        "tuned_beats_fixed_median": tuned["tokens_per_s"] >= median,
        "decode_scheme": decode_scheme,
        "verify_schemes": verify_schemes,
        "scheme_flipped": flipped,
    }
    print(f"spec  tuned_draft_len={out['tuned_draft_len']} "
          f"tuned_tput={tuned['tokens_per_s']:.1f} tok/s "
          f"(fixed median {median:.1f}) "
          f"beats_median={out['tuned_beats_fixed_median']} "
          f"decode_scheme={decode_scheme} "
          f"verify_schemes={verify_schemes} flip={flipped}")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--n-layers", type=int, default=2)
    ap.add_argument("--slots", default="2,4")
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=6)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--chunk-tokens", type=int, default=0,
                    help="step token budget of the paged_chunked cells "
                         "(0 = auto: max(16, mix max_len // 4))")
    ap.add_argument("--long-max-len", type=int, default=768,
                    help="cache depth of the long_prompt mix (the "
                         "admission stall needs prompts long enough that "
                         "prefill cost is token-dominated, not "
                         "dispatch-dominated)")
    ap.add_argument("--mixes", default=None,
                    help="comma-separated subset of mixes to run "
                         f"(default all: {','.join(MIXES)})")
    ap.add_argument("--mesh", default=None,
                    help="comma-separated tensor-parallel widths (e.g. "
                         "'1,2,4'): adds a sharded sweep — params + paged "
                         "KV sharded over a (data=1, model=N) mesh, bf16, "
                         "per-shard intensity-guided plans — reporting "
                         "tokens/s, the per-shard scheme table, and "
                         "stream equality vs the width-1 baseline (widths "
                         "beyond the visible device count are skipped; "
                         "use XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=8 on CPU)")
    ap.add_argument("--quick", action="store_true",
                    help="one slot count, two schemes")
    ap.add_argument("--out", default=None, help="write JSON here (else stdout)")
    ap.add_argument("--telemetry-out", default=None,
                    help="write a per-cell telemetry artifact: metrics "
                         "snapshot, fault-rate surface, and a bounded "
                         "span trace per engine cell (schema-gated in "
                         "CI by benchmarks/check_telemetry_schema.py)")
    args = ap.parse_args(argv)

    cfg = scaled_down(get_config(args.arch), n_layers=args.n_layers)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)

    slot_counts = [int(s) for s in str(args.slots).split(",")]
    schemes = dict(SCHEMES)
    if args.quick:
        slot_counts = slot_counts[:1]
        schemes = {k: schemes[k] for k in ("none", "intensity_guided")}
    mixes = dict(MIXES)
    if args.mixes:
        names = [m.strip() for m in str(args.mixes).split(",") if m.strip()]
        unknown = [m for m in names if m not in MIXES]
        if unknown:
            raise SystemExit(f"unknown mixes {unknown}; known: {list(MIXES)}")
        mixes = {m: MIXES[m] for m in names}

    share_ok = model.supports_prefix_sharing
    chunk_ok = model.supports_chunked_prefill
    cells = []
    telemetry_cells = []
    for slots in slot_counts:
        for mix_name, mix in mixes.items():
            n_reqs = args.requests
            if mix_name == "templated":
                # enough waves that the steady state (one resident
                # template chained through overlapping sharers) dominates
                # the cold-start wave of unshared copies
                n_reqs = max(args.requests, 6 * slots)
            mix_max_len = (max(args.max_len, args.long_max_len)
                           if mix_name == "long_prompt" else args.max_len)
            chunk_tokens = (args.chunk_tokens
                            or max(16, mix_max_len // 4))
            reqs_proto, lens = _requests(
                mix, n_reqs, mix_max_len, args.new_tokens)
            peak_new = max(r.max_new_tokens for r in reqs_proto)
            nb = _pool_blocks(lens, slots, peak_new, args.block_size)
            kinds = ["dense", "paged"]
            if share_ok:
                kinds.append("paged_shared")
            if chunk_ok:
                kinds.append("paged_chunked")
            for scheme_name, abft in schemes.items():
                row = {"slots": slots, "mix": mix_name,
                       "scheme": scheme_name,
                       "max_len": mix_max_len,
                       # the EFFECTIVE step budget the paged_chunked cell
                       # ran with (the --chunk-tokens flag may be 0=auto)
                       "chunk_tokens": chunk_tokens,
                       "prompt_lens": lens}
                streams = {}
                for kind in kinds:
                    reqs = [Request(uid=r.uid, prompt=r.prompt,
                                    max_new_tokens=r.max_new_tokens)
                            for r in reqs_proto]
                    # one fresh telemetry per cell (counters mirror ONE
                    # engine's stats); the trace is event-bounded so the
                    # artifact stays small across the whole sweep
                    tel = (EngineTelemetry(trace=True,
                                           trace_max_events=2000)
                           if args.telemetry_out else None)
                    cell = run_cell(
                        model, params, reqs, slots=slots,
                        max_len=mix_max_len, abft=abft,
                        cache_kind="dense" if kind == "dense" else "paged",
                        block_size=args.block_size,
                        num_blocks=None if kind == "dense" else nb,
                        prefix_sharing=(kind == "paged_shared"),
                        chunk_tokens=(chunk_tokens
                                      if kind == "paged_chunked" else None),
                        telemetry=tel)
                    if tel is not None:
                        telemetry_cells.append(dict(
                            {"slots": slots, "mix": mix_name,
                             "scheme": scheme_name, "kind": kind},
                            **cell.pop("telemetry")))
                    streams[kind] = cell.pop("streams")
                    row[kind] = cell
                row["paged_matches_dense"] = (
                    streams["dense"] == streams["paged"])
                row["paged_bytes_frac"] = (
                    row["paged"]["cache_bytes"]
                    / max(row["dense"]["cache_bytes"], 1))
                shared_note = ""
                if share_ok:
                    row["shared_matches_dense"] = (
                        streams["dense"] == streams["paged_shared"])
                    # the acceptance metric: steady-state resident blocks
                    # at equal throughput, shared vs unshared paging (the
                    # median discounts the cold-start wave, which by
                    # construction cannot share — nothing is cached yet)
                    row["shared_blocks_frac"] = (
                        row["paged_shared"]["blocks_used_median"]
                        / max(row["paged"]["blocks_used_median"], 1e-9))
                    shared_note = (
                        f" shared_blocks={row['shared_blocks_frac']:.2f}x "
                        f"hit={row['paged_shared']['prefix_hit_rate']:.2f} "
                        f"match={row['shared_matches_dense']}")
                auto_note = ""
                if chunk_ok and mix_name == "long_prompt" and \
                        scheme_name == "intensity_guided":
                    # chunked_auto: the budget comes from the plan's
                    # roofline autotuner (smallest mixed-step budget
                    # clearing the AUTO_TUNE_HW CMR with the modeled
                    # 10% throughput margin) instead of a flag.
                    # Acceptance: streams stay byte-identical to dense,
                    # the tuned budget clears the CMR, and modeled
                    # throughput lands within 10% of the best FIXED
                    # budget from the half/double bracketing sweep (run
                    # under the SAME hardware spec, so the comparison is
                    # budget-vs-budget, not scheme-vs-scheme).
                    auto_abft = dataclasses.replace(
                        abft, hardware=AUTO_TUNE_HW)
                    auto_cell = run_cell(
                        model, params,
                        [Request(uid=r.uid, prompt=r.prompt,
                                 max_new_tokens=r.max_new_tokens)
                         for r in reqs_proto],
                        slots=slots, max_len=mix_max_len,
                        abft=auto_abft,
                        cache_kind="paged", block_size=args.block_size,
                        num_blocks=nb, chunk_tokens="auto")
                    streams["chunked_auto"] = auto_cell.pop("streams")
                    row["chunked_auto"] = auto_cell
                    auto_b = auto_cell["chunk_budget"]
                    row["auto_budget"] = auto_b
                    row["auto_matches_dense"] = (
                        streams["dense"] == streams["chunked_auto"])
                    row["auto_clears_cmr"] = compute_bound_ai(
                        auto_cell["mixed_step_intensity"], AUTO_TUNE_HW)
                    sweep = {}
                    for b in sorted({max(8, auto_b // 2 // 8 * 8),
                                     2 * auto_b, chunk_tokens}):
                        scell = run_cell(
                            model, params,
                            [Request(uid=r.uid, prompt=r.prompt,
                                     max_new_tokens=r.max_new_tokens)
                             for r in reqs_proto],
                            slots=slots, max_len=mix_max_len,
                            abft=auto_abft, cache_kind="paged",
                            block_size=args.block_size,
                            num_blocks=nb, chunk_tokens=b)
                        s_streams = scell.pop("streams")
                        sweep[str(b)] = {
                            "tokens_per_s": scell["tokens_per_s"],
                            "modeled_step_tput":
                                scell["modeled_step_tput"],
                            "matches_dense":
                                s_streams == streams["dense"],
                        }
                    row["fixed_budget_sweep"] = sweep
                    row["auto_tput_frac"] = (
                        auto_cell["tokens_per_s"]
                        / max(max(v["tokens_per_s"]
                                  for v in sweep.values()), 1e-9))
                    row["auto_modeled_tput_frac"] = (
                        auto_cell["modeled_step_tput"]
                        / max(max(v["modeled_step_tput"]
                                  for v in sweep.values()), 1e-9))
                    auto_note = (
                        f" auto_budget={row['auto_budget']}"
                        f" auto_tput={row['auto_tput_frac']:.2f}x"
                        f" (modeled {row['auto_modeled_tput_frac']:.2f}x)"
                        f" clears_cmr={row['auto_clears_cmr']}")
                chunk_note = ""
                if chunk_ok:
                    # the chunked-prefill acceptance metrics: byte-equal
                    # streams, equal-throughput p99 ITL vs the admit-time
                    # -prefill paged baseline (the long_prompt mix is the
                    # cell where the stall lives)
                    row["chunked_matches_dense"] = (
                        streams["dense"] == streams["paged_chunked"])
                    row["chunked_itl_p99_frac"] = (
                        row["paged_chunked"]["itl_ms"]["p99"]
                        / max(row["paged"]["itl_ms"]["p99"], 1e-9))
                    row["chunked_tput_frac"] = (
                        row["paged_chunked"]["tokens_per_s"]
                        / max(row["paged"]["tokens_per_s"], 1e-9))
                    chunk_note = (
                        f" chunked_itl_p99={row['chunked_itl_p99_frac']:.2f}x"
                        f" match={row['chunked_matches_dense']}")
                cells.append(row)
                print(f"slots={slots} mix={mix_name:13s} "
                      f"scheme={scheme_name:16s} "
                      f"dense={row['dense']['tokens_per_s']:8.1f} tok/s "
                      f"paged={row['paged']['tokens_per_s']:8.1f} tok/s "
                      f"bytes={row['paged_bytes_frac']:.2f}x "
                      f"match={row['paged_matches_dense']}"
                      + shared_note + chunk_note + auto_note)

    # speculative decoding needs the rollback guarantees chunked prefill
    # needs too (attention-only cache writes, no SSM recurrence)
    spec_sweep = _spec_sweep(model, params, args) if chunk_ok else None

    sharded = None
    if args.mesh:
        widths = sorted({int(w) for w in str(args.mesh).split(",")})
        ndev = len(jax.devices())
        # bf16: per-device partial GEMMs accumulate in f32 and round
        # below output precision, so streams stay byte-identical across
        # widths (the equality verdict below is exact, not approximate)
        params_b = model.init_params(jax.random.PRNGKey(0),
                                     dtype=jnp.bfloat16)
        abft = ABFTConfig(scheme=Scheme.AUTO, use_pallas=False,
                          hardware=SHARD_SWEEP_HW)
        reqs_proto, lens = _requests(MIXES["uniform_short"],
                                     args.requests, args.max_len,
                                     args.new_tokens)
        nb = _pool_blocks(lens, slot_counts[0], args.new_tokens,
                          args.block_size)
        rows, base_streams = [], None
        for w in widths:
            if w > ndev:
                rows.append({"mesh": w, "skipped":
                             f"needs {w} devices, have {ndev}"})
                print(f"mesh={w}: skipped ({w} > {ndev} devices)")
                continue
            cell = run_cell(
                model, params_b,
                [Request(uid=r.uid, prompt=r.prompt,
                         max_new_tokens=r.max_new_tokens)
                 for r in reqs_proto],
                slots=slot_counts[0], max_len=args.max_len, abft=abft,
                cache_kind="paged", block_size=args.block_size,
                num_blocks=nb, mesh=w, dtype=jnp.bfloat16)
            streams = cell.pop("streams")
            if base_streams is None:
                base_streams = streams
            cell["mesh"] = w
            cell["matches_mesh1"] = streams == base_streams
            rows.append(cell)
            schemes_now = collections.Counter(
                e["scheme"] for e in cell["shard_plan"])
            print(f"mesh={w} tok/s={cell['tokens_per_s']:8.1f} "
                  f"matches_mesh1={cell['matches_mesh1']} "
                  f"shard_schemes={dict(schemes_now)}")
        # the engine rows above carry decode-shaped plans (m = slots,
        # bandwidth-bound at smoke scale); the divergence the paper's
        # selector exhibits lives at prefill-representative token counts,
        # so also compile the per-width plans at n_tokens=64 — device-
        # independent, covers skipped widths too
        divergence = {}
        for w in widths:
            p = model.protection_plan(
                hw=SHARD_SWEEP_HW, phase="serve", n_tokens=64,
                dtype_bytes=2, model_parallel=w)
            divergence[str(w)] = {
                r["layer"]: r["scheme"] for r in p.report_rows()}
        flipped = sorted(
            layer for layer in divergence[str(widths[0])]
            if len({d[layer] for d in divergence.values()}) > 1)
        print(f"per-shard plan divergence (n_tokens=64): "
              f"{flipped or 'none'}")
        sharded = {"widths": widths, "devices": ndev,
                   "hardware": SHARD_SWEEP_HW.name, "rows": rows,
                   "plan_divergence": divergence,
                   "layers_flipping_scheme": flipped}

    summary = {
        "arch": args.arch, "n_layers": args.n_layers,
        "max_len": args.max_len, "requests": args.requests,
        "new_tokens": args.new_tokens, "block_size": args.block_size,
        "chunk_tokens_flag": args.chunk_tokens,   # 0 = auto, see cells
        "mixes": list(mixes),
        "backend": jax.default_backend(),
        "cells": cells,
        "spec_decode": spec_sweep,
        "sharded": sharded,
    }
    payload = json.dumps(summary, indent=2)
    if args.out:
        with open(args.out, "w") as fh:
            fh.write(payload)
        print(f"wrote {args.out}")
    else:
        print(payload)
    if args.telemetry_out:
        with open(args.telemetry_out, "w") as fh:
            json.dump({"schema_version": 1, "cells": telemetry_cells},
                      fh, indent=2)
        print(f"wrote {args.telemetry_out} "
              f"({len(telemetry_cells)} telemetry cells)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
