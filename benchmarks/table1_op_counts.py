"""Paper Table 1: additional matmul-unit ops and checksum ops per K step
for replication vs two-sided vs one-sided schemes — re-derived for the TPU
block-level kernel (per (bm x bn) output block per bk step).
"""

from __future__ import annotations

from benchmarks.common import row
from repro.core import BlockShape, GemmDims, Scheme, scheme_cost


def run() -> list:
    rows = []
    b = BlockShape(bm=256, bk=512, bn=256)
    d = GemmDims(m=4096, k=4096, n=4096)
    base_flops = d.flops
    for sc in (Scheme.REPLICA, Scheme.BLOCK_2S, Scheme.BLOCK_1S,
               Scheme.GLOBAL):
        c = scheme_cost(sc, d, b)
        rows.append(row(
            f"table1/{sc.value}", 0.0,
            extra_mxu_flops=c.flops_mxu,
            extra_vpu_flops=c.flops_vpu,
            extra_bytes=c.bytes_hbm,
            fixed_ops=c.fixed_ops,
            mxu_ratio=c.flops_mxu / base_flops,
            vpu_ratio=c.flops_vpu / base_flops,
        ))
    # Table-1 orderings (TPU form): replica maximizes matmul-unit ops with
    # zero checksum ops; two-sided minimizes both but loses location;
    # one-sided sits between on VPU ops and adds zero MXU ops.
    c_rep = scheme_cost(Scheme.REPLICA, d, b)
    c_2s = scheme_cost(Scheme.BLOCK_2S, d, b)
    c_1s = scheme_cost(Scheme.BLOCK_1S, d, b)
    rows.append(row(
        "table1/orderings", 0.0,
        replica_max_mxu=(c_rep.flops_mxu > c_1s.flops_mxu
                         and c_rep.flops_mxu > c_2s.flops_mxu),
        onesided_no_mxu=(c_1s.flops_mxu == 0.0),
        twosided_fewest_vpu=(c_2s.flops_vpu < c_1s.flops_vpu),
    ))
    return rows
