"""Schema + invariant gate for the fault-campaign benchmark JSON.

CI runs ``benchmarks/fault_campaign.py`` and then this script: a fresh
summary must contain every key path the committed baseline
(``BENCH_faults.json``) contains, and the campaign acceptance criteria
must hold cell by cell:

* **replay determinism** — every cell's ``replay_identical`` verdict is
  True (same seed -> same injection schedule -> same per-fault
  classification -> same streams);
* **no-regression with the fault model disabled** — every cell's
  ``disabled_matches_clean`` verdict is True;
* **zero SDCs under protection** — cells whose scheme protects
  (``traditional`` / ``intensity_guided`` / ``adaptive``) report
  ``sdc_faults == 0`` and full detection ``coverage`` (1.0 over the
  effective, non-masked injections) whenever any fault landed;
* **the harness sees real SDCs** — the unprotected ``none`` control
  cells report ``sdc_faults > 0`` (otherwise the shadow-stream
  classifier went blind, and the zero-SDC verdicts above are vacuous);
* **adaptive escalation** — every ``adaptive`` cell escalated at least
  once under the elevated injected rate, with a non-empty
  ``escalation_trace`` of ``protection_escalation`` instants carrying
  rate evidence, and the ``adaptive_quiet`` block proves the quiet
  regime matches the base intensity-guided engine (byte-identical
  streams, identical plan rows, zero escalations).

Cell coverage may differ (the CI smoke job runs ``--quick``, a subset);
the gate compares per-cell structure and per-cell invariants, not which
cells exist — but at least one protected cell must be present, and the
``none`` control is required only when present in the run.

  PYTHONPATH=src python benchmarks/check_campaign_schema.py new.json \
      [baseline.json]
"""

from __future__ import annotations

import json
import sys

PROTECTED_SCHEMES = ("traditional", "intensity_guided", "adaptive")

REQUIRED_CELL_KEYS = (
    "scheme", "kind", "rate", "seed", "faults_injected",
    "faults_corrected", "faults_uncorrected", "sdc_faults",
    "masked_faults", "coverage", "sdc_rate", "overhead",
    "replay_identical", "disabled_matches_clean",
    "protection_level_final", "protection_escalations",
    "escalation_trace", "schedule", "injection_log",
)


def key_paths(node, prefix=()) -> set:
    """All dict key paths in a JSON tree; list elements merge under one
    wildcard step so cell counts don't matter."""
    paths = set()
    if isinstance(node, dict):
        for k, v in node.items():
            paths.add(prefix + (k,))
            paths |= key_paths(v, prefix + (k,))
    elif isinstance(node, list):
        for item in node:
            paths |= key_paths(item, prefix + ("[]",))
    return paths


def check_cell(cell: dict, where: str) -> list:
    errors = []
    for k in REQUIRED_CELL_KEYS:
        if k not in cell:
            errors.append(f"{where}: missing key {k}")
    scheme = cell.get("scheme")
    injected = cell.get("faults_injected", 0)
    if cell.get("replay_identical") is not True:
        errors.append(f"{where}: replay_identical is not True — the "
                      "seeded campaign stopped replaying bit-identically")
    if cell.get("disabled_matches_clean") is not True:
        errors.append(f"{where}: disabled_matches_clean is not True — "
                      "attaching a silent fault model changed the "
                      "greedy streams")
    if scheme in PROTECTED_SCHEMES:
        if cell.get("sdc_faults", 1) != 0:
            errors.append(f"{where}: {cell.get('sdc_faults')} SDCs "
                          "under protection (must be zero)")
        if injected and cell.get("coverage") != 1.0:
            errors.append(f"{where}: detection coverage "
                          f"{cell.get('coverage')} != 1.0 under "
                          "protection")
    elif scheme == "none":
        if injected and cell.get("sdc_faults", 0) <= 0:
            errors.append(f"{where}: unprotected control saw no SDCs — "
                          "the shadow-stream classifier went blind")
    if scheme == "adaptive":
        if injected and cell.get("protection_escalations", 0) < 1:
            errors.append(f"{where}: adaptive cell never escalated "
                          "under the elevated injected rate")
        if injected and not cell.get("escalation_trace"):
            errors.append(f"{where}: adaptive cell has no "
                          "protection_escalation instants")
        for ev in cell.get("escalation_trace", []):
            if "level" not in ev or "direction" not in ev:
                errors.append(f"{where}: escalation instant lacks "
                              "level/direction evidence")
    # classification must partition the injections
    parts = (cell.get("faults_corrected", 0)
             + cell.get("faults_uncorrected", 0)
             + cell.get("sdc_faults", 0) + cell.get("masked_faults", 0))
    if parts > injected:
        errors.append(f"{where}: classification counts ({parts}) exceed "
                      f"faults_injected ({injected})")
    if len(cell.get("schedule", ())) != injected:
        errors.append(f"{where}: schedule length "
                      f"{len(cell.get('schedule', ()))} != "
                      f"faults_injected {injected}")
    return errors


def check(new: dict, baseline: dict) -> list:
    errors = []
    missing = sorted(key_paths(baseline) - key_paths(new),
                     key=lambda p: (len(p), p))
    # per-fault dict contents under these vary with which faults fired
    # (e.g. a quick run with no adaptive de-escalation, or no sticky
    # permanents) — their sub-keys are not a schema regression
    _VARIABLE = ("schedule", "injection_log", "escalation_trace")
    missing = [p for p in missing if not (set(p) & set(_VARIABLE))]
    for p in missing:
        errors.append(f"missing key path: {'.'.join(p)}")

    cells = new.get("cells", [])
    if not cells:
        errors.append("no cells in summary")
    for i, cell in enumerate(cells):
        where = f"cells[{i}] ({cell.get('scheme')}/{cell.get('kind')})"
        errors += check_cell(cell, where)
    if not any(c.get("scheme") in PROTECTED_SCHEMES for c in cells):
        errors.append("no protected-scheme cell in the run — the "
                      "zero-SDC criterion was never exercised")

    quiet = new.get("adaptive_quiet")
    if not isinstance(quiet, dict):
        errors.append("missing adaptive_quiet block")
    else:
        if quiet.get("streams_match") is not True:
            errors.append("adaptive_quiet: streams diverged from the "
                          "base intensity-guided engine")
        if quiet.get("plan_rows_match") is not True:
            errors.append("adaptive_quiet: per-layer plan rows diverged "
                          "from the base policy")
        if quiet.get("escalations", 1) != 0:
            errors.append("adaptive_quiet: the adaptive policy escalated "
                          "with no faults injected (flapping)")
    return errors


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    if not argv:
        print(__doc__)
        return 2
    new_path = argv[0]
    base_path = argv[1] if len(argv) > 1 else "BENCH_faults.json"
    with open(new_path) as fh:
        new = json.load(fh)
    with open(base_path) as fh:
        baseline = json.load(fh)
    errors = check(new, baseline)
    if errors:
        for e in errors:
            print(f"CAMPAIGN SCHEMA: {e}")
        return 1
    print(f"campaign schema OK: {new_path} covers {base_path} "
          f"({len(new['cells'])} cells)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
