"""Paper Fig. 12: execution-time overhead of each redundancy scheme on
square matrix multiplications of varying size.

Reproduces the paper's central crossover result on both devices:
  * NVIDIA T4 (the paper's device, FP16 CMR=203) — validates against the
    published claims: thread/block-level ABFT wins below the CMR (paper:
    up to 6.5x lower overhead), global wins above (paper: up to 14x),
    replication spikes for large sizes.
  * TPU v5e (our target, bf16 CMR~240) — the same structure with the
    TPU-adapted cost model (VPU checksums co-issue with the MXU).

Also measures the *actual* CPU wall time of the fused Pallas kernel
(interpret mode) vs an unprotected matmul for small sizes — a correctness-
of-costing sanity check, not a TPU perf claim.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_call
from repro.core import NVIDIA_T4, TPU_V5E, GemmDims, Scheme, overhead_pct
from repro.kernels import abft_matmul

SIZES = [32, 64, 128, 256, 512, 1024, 2048, 4096, 8192]
SCHEMES = [Scheme.GLOBAL, Scheme.BLOCK_1S, Scheme.BLOCK_2S, Scheme.REPLICA]


def run() -> list:
    rows = []
    for hw in (NVIDIA_T4, TPU_V5E):
        for s in SIZES:
            d = GemmDims(m=s, k=s, n=s)
            ovh = {sc: overhead_pct(sc, d, hw) for sc in SCHEMES}
            ai = d.arithmetic_intensity
            best = min(SCHEMES, key=lambda sc: ovh[sc])
            from repro.core import select_scheme
            guided = select_scheme(d, hw).scheme
            rows.append(row(
                f"fig12/{hw.name}/square_{s}", 0.0,
                ai=ai, cmr=hw.cmr,
                regime="bandwidth" if ai < hw.cmr else "compute",
                **{f"ovh_{sc.value}": ovh[sc] for sc in SCHEMES},
                intensity_guided=guided.value,
                best_of_all=best.value,
            ))
        # paper-claim validation rows (T4): block beats global below CMR,
        # global beats replication above, replication spikes when compute
        # bound
        small = GemmDims(m=128, k=128, n=128)
        big = GemmDims(m=4096, k=4096, n=4096)
        rows.append(row(
            f"fig12/{hw.name}/claims", 0.0,
            block_wins_small=overhead_pct(Scheme.BLOCK_1S, small, hw)
            < overhead_pct(Scheme.GLOBAL, small, hw),
            global_wins_big=overhead_pct(Scheme.GLOBAL, big, hw)
            <= overhead_pct(Scheme.REPLICA, big, hw),
            replica_spike_pct=overhead_pct(Scheme.REPLICA, big, hw),
        ))

    # measured CPU wall time: fused kernel (interpret) vs plain matmul
    rng = np.random.default_rng(0)
    for s in (128, 256):
        x = jnp.asarray(rng.standard_normal((s, s)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((s, s)), jnp.float32)
        t_plain = time_call(lambda a, b: a @ b, x, w)
        t_abft = time_call(
            lambda a, b: abft_matmul(a, b, mode="1s",
                                     out_dtype=jnp.float32)[0], x, w)
        rows.append(row(
            f"fig12/measured_cpu_interpret/square_{s}", t_abft,
            plain_us=t_plain,
            note="interpret-mode-correctness-check-not-tpu-perf"))
    return rows
