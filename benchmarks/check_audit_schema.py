"""Schema + invariant gate for the protection-coverage audit JSON.

CI runs ``python -m repro.launch.audit --all --json audit_coverage.json``
and then this script: the fresh report must contain every key path the
committed baseline (``AUDIT_coverage.json``) contains — including every
audited config name — plus the audit's own acceptance invariants.  A
model change that silently drops a config from the audit, de-registers a
protected site, or reintroduces an unmarked GEMM fails the job instead
of shipping.

  PYTHONPATH=src python benchmarks/check_audit_schema.py new.json \
      [baseline.json]
"""

from __future__ import annotations

import json
import sys

# keys whose presence depends on the model family, not the schema:
# known-unprotected kinds only exist for the archs that have the region,
# and per-op diagnostic lists are empty when coverage is clean
_CONDITIONAL = {"mla", "ssm_scan", "conv_stem", "unprotected",
                "dim_mismatches", "plan_only", "trace_only"}


def key_paths(node, prefix=()) -> set:
    """All dict key paths in a JSON tree; list elements merge under one
    wildcard step so entry counts don't matter."""
    paths = set()
    if isinstance(node, dict):
        for k, v in node.items():
            paths.add(prefix + (k,))
            paths |= key_paths(v, prefix + (k,))
    elif isinstance(node, list):
        for item in node:
            paths |= key_paths(item, prefix + ("[]",))
    return paths


def check(new: dict, baseline: dict) -> list:
    errors = []
    if new.get("schema") != baseline.get("schema"):
        errors.append(
            f"schema id {new.get('schema')!r} != "
            f"baseline {baseline.get('schema')!r}")

    missing = sorted(
        key_paths(baseline) - key_paths(new),
        key=lambda p: (len(p), p))
    missing = [p for p in missing if not (set(p) & _CONDITIONAL)]
    for p in missing:
        errors.append(f"missing key path: {'.'.join(p)}")

    for name, rep in sorted(new.get("configs", {}).items()):
        frac = rep.get("protected_fraction")
        if not isinstance(frac, (int, float)) or not 0.0 <= frac <= 1.0:
            errors.append(f"{name}: protected_fraction {frac!r} not in "
                          "[0, 1]")
        elif frac < 1.0:
            errors.append(
                f"{name}: protected fraction {frac:.4f} < 1.0 — an "
                "unmarked FLOP-carrying primitive reached the traced "
                "entry points")
        if not rep.get("crosscheck", {}).get("bijective"):
            errors.append(f"{name}: plan <-> trace crosscheck is not "
                          "bijective (stale or drifted ProtectionPlan)")
        for ph, cov in sorted(rep.get("phases", {}).items()):
            for op in cov.get("unprotected", []):
                errors.append(
                    f"{name}.{ph}: UNPROTECTED {op.get('primitive')} "
                    f"({op.get('flops'):.3g} flops) at {op.get('path')}")
            for kind, gap in sorted(
                    cov.get("known_unprotected", {}).items()):
                if not gap.get("note"):
                    errors.append(
                        f"{name}.{ph}: known-unprotected kind {kind!r} "
                        "has no disposition note")
        if rep.get("flash_consistent") is False:
            errors.append(
                f"{name}: flash allowlist inconsistent — softmax dots "
                "survive a flash-enabled decode trace")
    if not new.get("configs"):
        errors.append("no configs in report")
    return errors


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    if not argv:
        print(__doc__)
        return 2
    new_path = argv[0]
    base_path = argv[1] if len(argv) > 1 else "AUDIT_coverage.json"
    with open(new_path) as fh:
        new = json.load(fh)
    with open(base_path) as fh:
        baseline = json.load(fh)
    errors = check(new, baseline)
    if errors:
        for e in errors:
            print(f"AUDIT REGRESSION: {e}")
        return 1
    print(f"audit schema OK: {new_path} covers {base_path} "
          f"({len(new['configs'])} configs)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
