"""Schema regression gate for the serving benchmark JSON.

CI runs the benchmark smoke job and then this script: a freshly produced
summary must contain every key path the committed baseline
(``BENCH_serve.json``) contains, plus basic sanity invariants (percentile
ordering, positive throughput, present stream-equality verdicts).  A
refactor that silently drops a reported metric — the way the perf
trajectory would quietly stop being tracked — fails the job instead of
shipping.

Mix coverage may differ (the smoke job runs a subset of mixes); the gate
compares the *per-cell structure*, not which cells exist.

  PYTHONPATH=src python benchmarks/check_bench_schema.py new.json \
      [baseline.json]
"""

from __future__ import annotations

import json
import sys

# cell keys that only exist when the model supports the feature (or, for
# the chunked_auto group, only on the long_prompt/intensity_guided cell) —
# their absence in a run on e.g. a hybrid arch is not a schema regression
_CONDITIONAL = {
    "paged_shared", "shared_matches_dense", "shared_blocks_frac",
    "paged_chunked", "chunked_matches_dense", "chunked_itl_p99_frac",
    "chunked_tput_frac",
    # chunked-prefill budget keys (only on cells run with a budget)
    "chunk_budget", "budget_retunes", "mixed_step_intensity", "cmr",
    "modeled_step_tput",
    # roofline-autotuned budget cell + its acceptance keys
    "chunked_auto", "auto_budget", "auto_matches_dense",
    "auto_clears_cmr", "auto_tput_frac", "auto_modeled_tput_frac",
    "fixed_budget_sweep",
    # the --mesh sharded sweep (null when the flag is not passed; its
    # sub-tree keys all sit under "sharded" so one entry covers them)
    "sharded",
    # the speculative-decoding sweep (null on models without the
    # rollback guarantees — same gate as chunked prefill; the --quick
    # smoke also runs fewer proposers/K values than the baseline)
    "spec_decode",
}


def key_paths(node, prefix=()) -> set:
    """All dict key paths in a JSON tree; list elements merge under one
    wildcard step so cell counts don't matter."""
    paths = set()
    if isinstance(node, dict):
        for k, v in node.items():
            paths.add(prefix + (k,))
            paths |= key_paths(v, prefix + (k,))
    elif isinstance(node, list):
        for item in node:
            paths |= key_paths(item, prefix + ("[]",))
    return paths


def check(new: dict, baseline: dict) -> list:
    errors = []
    missing = sorted(
        key_paths(baseline) - key_paths(new),
        key=lambda p: (len(p), p))
    # children of selection.schemes are per-step selection COUNTS — which
    # schemes appear depends on the traffic mix, not on the schema
    missing = [p for p in missing
               if not (set(p) & _CONDITIONAL) and "schemes" not in p[:-1]]
    for p in missing:
        errors.append(f"missing key path: {'.'.join(p)}")

    for i, cell in enumerate(new.get("cells", [])):
        where = f"cells[{i}] ({cell.get('mix')}/{cell.get('scheme')})"
        for kind, payload in cell.items():
            if not isinstance(payload, dict):
                continue
            if payload.get("tokens_per_s", 1) <= 0:
                errors.append(f"{where}.{kind}: tokens_per_s <= 0")
            for lat in ("ttft_ms", "itl_ms"):
                pct = payload.get(lat)
                if pct is None:
                    continue
                if not (pct["p50"] <= pct["p95"] <= pct["p99"]):
                    errors.append(
                        f"{where}.{kind}.{lat}: percentiles not ordered "
                        f"({pct})")
        for verdict in ("paged_matches_dense", "chunked_matches_dense",
                        "shared_matches_dense", "auto_matches_dense"):
            if cell.get(verdict) is False:
                errors.append(f"{where}: {verdict} is False — greedy "
                              "streams diverged")
        for budget, entry in cell.get("fixed_budget_sweep", {}).items():
            if entry.get("matches_dense") is False:
                errors.append(f"{where}: fixed budget {budget} streams "
                              "diverged from dense")
        if cell.get("auto_clears_cmr") is False:
            errors.append(f"{where}: auto chunk budget does not clear "
                          "the CMR (tune_chunk_budget regression)")
        if "auto_modeled_tput_frac" in cell and \
                cell["auto_modeled_tput_frac"] < 0.9:
            errors.append(
                f"{where}: auto budget's modeled throughput is "
                f"{cell['auto_modeled_tput_frac']:.2f}x the best fixed "
                "budget (acceptance: within 10%)")
    spec = new.get("spec_decode")
    if spec:
        srows = list(spec.get("rows", []))
        if spec.get("tuned"):
            srows.append(spec["tuned"])
        for row in srows:
            where = (f"spec_decode ({row.get('scheme')}/"
                     f"{row.get('proposer')}/K={row.get('draft_len')})")
            if row.get("spec_matches_dense") is False:
                errors.append(
                    f"{where}: spec_matches_dense is False — speculative "
                    "streams diverged from the unsped baseline")
            s = row.get("spec", {})
            if s.get("draft_accepted", 0) > s.get("draft_proposed", 0):
                errors.append(f"{where}: draft_accepted exceeds "
                              "draft_proposed")
        if spec.get("tuned_beats_fixed_median") is False:
            errors.append(
                "spec_decode: tuned draft length loses to the fixed-K "
                "median (tune_draft_len regression)")
        if spec.get("scheme_flipped") is False:
            errors.append(
                "spec_decode: the K-scaled verify window no longer "
                "crosses the CMR — per-step scheme selection stopped "
                "flipping between decode and verify compositions")
    for i, row in enumerate((new.get("sharded") or {}).get("rows", [])):
        where = f"sharded.rows[{i}] (mesh={row.get('mesh')})"
        if "skipped" in row:
            continue
        if row.get("matches_mesh1") is False:
            errors.append(f"{where}: matches_mesh1 is False — greedy "
                          "streams diverged across mesh widths")
        if row.get("tokens_per_s", 1) <= 0:
            errors.append(f"{where}: tokens_per_s <= 0")
        if not row.get("shard_plan"):
            errors.append(f"{where}: missing per-shard protection plan")
        elif row.get("model_parallel") != row.get("mesh"):
            errors.append(f"{where}: model_parallel "
                          f"{row.get('model_parallel')} != mesh width")
    sharded = new.get("sharded")
    if sharded and len(sharded.get("widths", [])) > 1 and \
            not sharded.get("layers_flipping_scheme"):
        errors.append(
            "sharded: no layer changes scheme across mesh widths — the "
            "per-shard intensity-guided selection stopped diverging")
    if not new.get("cells"):
        errors.append("no cells in summary")
    return errors


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    if not argv:
        print(__doc__)
        return 2
    new_path = argv[0]
    base_path = argv[1] if len(argv) > 1 else "BENCH_serve.json"
    with open(new_path) as fh:
        new = json.load(fh)
    with open(base_path) as fh:
        baseline = json.load(fh)
    errors = check(new, baseline)
    if errors:
        for e in errors:
            print(f"SCHEMA REGRESSION: {e}")
        return 1
    print(f"schema OK: {new_path} covers {base_path} "
          f"({len(new['cells'])} cells)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
