# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver: one module per paper table/figure.

  fig4   aggregate arithmetic intensity per network      (paper Fig. 4)
  fig5   per-layer AI heterogeneity + per-site selection (paper Fig. 5)
  fig8   per-network ABFT overhead, 3 schemes            (paper Figs. 8-11)
  fig12  square-GEMM scheme sweep + crossovers           (paper Fig. 12)
  table1 per-scheme redundant-op accounting              (paper Table 1)
  roofline  dry-run roofline terms per cell              (EXPERIMENTS §Roofline)
"""

import sys


def main() -> None:
    from benchmarks import (
        fig4_aggregate_intensity,
        fig5_layer_intensity,
        fig8_11_overhead,
        fig12_square_sweep,
        roofline_summary,
        table1_op_counts,
    )

    modules = {
        "fig4": fig4_aggregate_intensity,
        "fig5": fig5_layer_intensity,
        "fig8": fig8_11_overhead,
        "fig12": fig12_square_sweep,
        "table1": table1_op_counts,
        "roofline": roofline_summary,
    }
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for name, mod in modules.items():
        if only and name != only:
            continue
        for r in mod.run():
            print(r)


if __name__ == "__main__":
    main()
