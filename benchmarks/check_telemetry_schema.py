"""Schema gate for serving telemetry artifacts.

Two artifact shapes are accepted:

* the **benchmark artifact** (``serve_throughput.py --telemetry-out``):
  ``{"schema_version": 1, "cells": [{slots, mix, scheme, kind, metrics,
  faultrate, trace, trace_events, counters_match_stats}, ...]}``;
* the **driver snapshot** (``repro.launch.serve --metrics-out``): one
  cell-shaped object with ``metrics``/``faultrate``/``engine_stats``/
  ``counters_match_stats`` (pass ``--trace t.json`` to also validate the
  matching ``--trace-out`` file).

Checked invariants (the exportable-telemetry acceptance criteria):

* every mirrored engine counter is present and the artifact's
  ``counters_match_stats`` verdict is True — plus, when the artifact
  embeds ``engine_stats``, the counter values are re-checked against it
  here (the gate does not trust the producer's own verdict);
* histograms are well-formed: cumulative bucket counts are
  non-decreasing, bucket bounds strictly increasing, the ``+Inf`` count
  equals ``count``;
* the fault-rate surface carries the windowed + EWMA keys ROADMAP 5b's
  adaptive policy consumes;
* trace events parse under Perfetto's JSON schema assumptions
  (``repro.obs.trace.check_events``: known phases, non-negative
  ``ts``/``dur``, proper span nesting per thread).

  PYTHONPATH=src python benchmarks/check_telemetry_schema.py \
      telemetry.json [--trace trace.json]
"""

from __future__ import annotations

import json
import sys

from repro.obs import ENGINE_COUNTERS
from repro.obs.trace import check_events

REQUIRED_FAULTRATE_KEYS = (
    "window", "window_detection_rate", "window_detection_rate_per_token",
    "window_retry_rate", "window_hard_fault_rate",
    "ewma_detections_per_step", "total_detections", "total_steps",
)

REQUIRED_HISTOGRAMS = (
    "serve_step_latency_seconds", "serve_ttft_seconds",
    "serve_itl_seconds",
)


def _counter_value(metrics: dict, name: str):
    m = metrics.get(name)
    if not m or m.get("type") != "counter" or not m.get("series"):
        return None
    return m["series"][0].get("value")


def check_metrics(metrics: dict, where: str,
                  engine_stats: dict | None = None) -> list:
    errors = []
    for name in ENGINE_COUNTERS:
        v = _counter_value(metrics, name)
        if v is None:
            errors.append(f"{where}: missing engine counter {name}")
        elif engine_stats is not None and name in engine_stats and \
                v != engine_stats[name]:
            errors.append(
                f"{where}: {name}={v} != engine_stats {engine_stats[name]}")
    for name in REQUIRED_HISTOGRAMS:
        m = metrics.get(name)
        if not m or m.get("type") != "histogram":
            errors.append(f"{where}: missing histogram {name}")
            continue
        for s in m.get("series", []):
            buckets = s.get("buckets", [])
            if not buckets or buckets[-1][0] != "+Inf":
                errors.append(f"{where}: {name} lacks a +Inf bucket")
                continue
            counts = [c for _, c in buckets]
            if counts != sorted(counts):
                errors.append(
                    f"{where}: {name} cumulative counts decrease")
            bounds = [le for le, _ in buckets[:-1]]
            if bounds != sorted(set(bounds)):
                errors.append(
                    f"{where}: {name} bounds not strictly increasing")
            if counts[-1] != s.get("count"):
                errors.append(
                    f"{where}: {name} +Inf count {counts[-1]} != "
                    f"count {s.get('count')}")
    return errors


def check_cell(cell: dict, where: str) -> list:
    errors = []
    metrics = cell.get("metrics")
    if not isinstance(metrics, dict):
        return [f"{where}: no metrics snapshot"]
    errors += check_metrics(metrics, where, cell.get("engine_stats"))
    # speculative decoding: acceptance can never exceed proposal (the
    # verify step accepts a prefix of what the proposer offered)
    dp = _counter_value(metrics, "serve_spec_draft_proposed_total")
    da = _counter_value(metrics, "serve_spec_draft_accepted_total")
    if dp is not None and da is not None and da > dp:
        errors.append(
            f"{where}: serve_spec_draft_accepted_total {da} > "
            f"serve_spec_draft_proposed_total {dp}")
    if cell.get("counters_match_stats") is False:
        errors.append(
            f"{where}: counters_match_stats is False — mirrored "
            "counters drifted from EngineStats")
    fr = cell.get("faultrate")
    if not isinstance(fr, dict):
        errors.append(f"{where}: no faultrate surface")
    else:
        for k in REQUIRED_FAULTRATE_KEYS:
            if k not in fr:
                errors.append(f"{where}: faultrate missing {k}")
    events = cell.get("trace_events")
    if events is not None:
        for p in check_events(events):
            errors.append(f"{where}: trace: {p}")
    return errors


def check(doc: dict, trace_doc: dict | None = None) -> list:
    errors = []
    if "cells" in doc:
        if not doc["cells"]:
            errors.append("no telemetry cells")
        for i, cell in enumerate(doc["cells"]):
            where = (f"cells[{i}] ({cell.get('mix')}/"
                     f"{cell.get('scheme')}/{cell.get('kind')})")
            errors += check_cell(cell, where)
    else:
        errors += check_cell(doc, "snapshot")
    if trace_doc is not None:
        events = trace_doc.get("traceEvents")
        if not isinstance(events, list) or not events:
            errors.append("trace file: no traceEvents array")
        else:
            errors += [f"trace file: {p}" for p in check_events(events)]
    return errors


def main(argv=None) -> int:
    argv = list(argv if argv is not None else sys.argv[1:])
    trace_path = None
    if "--trace" in argv:
        i = argv.index("--trace")
        trace_path = argv[i + 1]
        del argv[i:i + 2]
    if not argv:
        print(__doc__)
        return 2
    with open(argv[0]) as fh:
        doc = json.load(fh)
    trace_doc = None
    if trace_path:
        with open(trace_path) as fh:
            trace_doc = json.load(fh)
    errors = check(doc, trace_doc)
    if errors:
        for e in errors:
            print(f"TELEMETRY SCHEMA: {e}")
        return 1
    n = len(doc.get("cells", [doc]))
    print(f"telemetry schema OK: {argv[0]} ({n} cells"
          + (", trace valid" if trace_doc is not None else "") + ")")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
