"""Fault-campaign sweep: continuous seeded injection over the serving
engine, per-fault classification, and the error-rate-adaptive policy
under a sustained elevated error environment (ROADMAP items 5b/5c).

Each cell runs one {scheme x fault-class x rate} combination end to end:

1. a **clean** run (no fault model) — the greedy reference streams and
   the wall-clock baseline for the overhead ratio;
2. the **campaign** run — a seeded ``FaultModel`` injects Bernoulli
   transient or sticky permanent faults every step, and the engine's
   shadow-stream harness classifies every injection as corrected /
   uncorrected / SDC / masked;
3. a **replay** run — a fresh ``FaultModel`` with the same seed must
   reproduce the identical injection schedule, per-fault classification,
   and output streams (the bit-identical-replay acceptance criterion);
4. a **disabled** run — ``FaultModel(transient_rate=0)`` attached: the
   streams must stay byte-identical to the clean reference (the
   fault-model-off no-regression criterion).

Reported per cell: detection ``coverage`` ((corrected + uncorrected) /
effective injections, where ``masked`` faults — physical no-ops whose
shadow state matches bit-for-bit — are excluded), ``sdc_rate``,
``overhead`` (campaign wall / clean wall, the detect+recompute cost
under load), and for the ``adaptive`` cells the escalation trace
(``protection_escalation`` instants with their rate evidence).

The ``adaptive`` scheme also runs a **quiet-regime** check: with the
fault model disabled the adaptive engine's streams and per-layer plan
must match the base intensity-guided engine exactly (no phantom
escalations, identical per-step scheme choices).

Schema + invariants are gated in CI by
``benchmarks/check_campaign_schema.py`` against the committed
``BENCH_faults.json``.

  PYTHONPATH=src python benchmarks/fault_campaign.py \
      [--quick] [--out BENCH_faults.json] [--seed 0] \
      [--rates 0.3,0.15] [--requests 6] [--new-tokens 6]

Wall-clock numbers are CPU-measured (this container); the overhead
ratio orders recovery cost, not TPU speed — see benchmarks/common.py.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, scaled_down
from repro.core import (
    ABFTConfig,
    ErrorAdaptivePolicy,
    FaultModel,
    FixedPolicy,
    IntensityGuidedPolicy,
    Scheme,
)
from repro.models import build_model
from repro.obs import EngineTelemetry
from repro.serve.engine import Request, ServeEngine

# scheme column: none (unprotected control — the harness must SEE its
# SDCs), traditional global-everywhere, the paper's intensity-guided
# selector, and the adaptive wrapper that escalates under observed rate
SCHEMES = ("none", "traditional", "intensity_guided", "adaptive")

# fault classes: one-step transients vs sticky permanents (the arxiv
# 2205.12177 class a one-shot fault_at never exercises)
FAULT_KINDS = ("transient", "permanent")


def _abft(scheme: str, *, threshold: float = 0.05) -> ABFTConfig:
    if scheme == "none":
        return ABFTConfig.off()
    if scheme == "traditional":
        return ABFTConfig.from_policy(FixedPolicy(Scheme.GLOBAL),
                                      use_pallas=False)
    if scheme == "intensity_guided":
        return ABFTConfig.from_policy(IntensityGuidedPolicy(),
                                      use_pallas=False)
    if scheme == "adaptive":
        return ABFTConfig.from_policy(
            ErrorAdaptivePolicy(IntensityGuidedPolicy(),
                                detection_threshold=threshold,
                                deescalate_after=4),
            use_pallas=False)
    raise ValueError(f"unknown scheme {scheme!r}")


def _requests(n: int, new_tokens: int, vocab: int) -> list:
    rng = np.random.default_rng(0)
    return [
        Request(uid=i,
                prompt=rng.integers(
                    1, vocab, size=int(rng.integers(4, 12))).astype(
                    np.int32),
                max_new_tokens=new_tokens)
        for i in range(n)
    ]


def _fault_model(kind: str, rate: float, *, seed: int,
                 layers: int) -> FaultModel:
    # magnitude 1e4 keeps every landing fault far above the checksum
    # tolerance: protected cells must detect with certainty, unprotected
    # cells must visibly corrupt tokens — the benchmark's verdicts are
    # then deterministic functions of the seed
    return FaultModel(
        transient_rate=rate if kind == "transient" else 0.0,
        permanent_rate=rate if kind == "permanent" else 0.0,
        permanent_duration=4, seed=seed, layers=layers,
        dtype=jnp.float32, magnitude=1e4)


def _engine(model, params, *, slots, max_len, abft, fault_model=None,
            telemetry=None) -> ServeEngine:
    return ServeEngine(model, params, slots=slots, max_len=max_len,
                       abft=abft, dtype=jnp.float32,
                       fault_model=fault_model, telemetry=telemetry)


def _classification(stats) -> dict:
    return {
        "faults_injected": stats.faults_injected,
        "faults_corrected": stats.faults_corrected,
        "faults_uncorrected": stats.faults_uncorrected,
        "sdc_faults": stats.sdc_faults,
        "masked_faults": stats.masked_faults,
    }


def run_cell(model, params, cfg, *, scheme: str, kind: str, rate: float,
             seed: int, slots: int, max_len: int, requests: int,
             new_tokens: int, threshold: float) -> dict:
    def mk_reqs():
        return _requests(requests, new_tokens, cfg.vocab_size)
    abft = _abft(scheme, threshold=threshold)

    # 1. clean reference (also the jit warm-up for the timed runs)
    eng_clean = _engine(model, params, slots=slots, max_len=max_len,
                        abft=abft)
    t0 = time.perf_counter()
    clean = eng_clean.run(mk_reqs())
    clean_wall = time.perf_counter() - t0

    # 2. campaign run (traced telemetry captures escalation instants)
    fm = _fault_model(kind, rate, seed=seed, layers=cfg.n_layers)
    tel = EngineTelemetry(trace=True, trace_max_events=5000)
    eng = _engine(model, params, slots=slots, max_len=max_len, abft=abft,
                  fault_model=fm, telemetry=tel)
    t0 = time.perf_counter()
    campaign = eng.run(mk_reqs())
    campaign_wall = time.perf_counter() - t0
    stats = eng.stats
    cls = _classification(stats)
    effective = cls["faults_injected"] - cls["masked_faults"]
    detected = cls["faults_corrected"] + cls["faults_uncorrected"]
    escalations = [
        dict(e["args"]) for e in tel.tracer.events
        if e.get("name") == "protection_escalation"]

    # 3. bit-identical replay from the same seed
    fm2 = _fault_model(kind, rate, seed=seed, layers=cfg.n_layers)
    eng2 = _engine(model, params, slots=slots, max_len=max_len,
                   abft=abft, fault_model=fm2)
    replay = eng2.run(mk_reqs())
    replay_identical = (
        fm.schedule == fm2.schedule
        and stats.injection_log == eng2.stats.injection_log
        and campaign == replay)

    # 4. fault model attached but silent: streams must equal clean
    fm_off = FaultModel(transient_rate=0.0, seed=seed)
    eng_off = _engine(model, params, slots=slots, max_len=max_len,
                      abft=abft, fault_model=fm_off)
    disabled_matches_clean = (eng_off.run(mk_reqs()) == clean
                              and eng_off.stats.faults_injected == 0)

    cell = {
        "scheme": scheme, "kind": kind, "rate": rate, "seed": seed,
        **cls,
        "hard_faults": stats.hard_faults,
        "evictions": stats.evictions,
        "coverage": (detected / effective) if effective else 1.0,
        "sdc_rate": (cls["sdc_faults"] / cls["faults_injected"]
                     if cls["faults_injected"] else 0.0),
        "overhead": campaign_wall / max(clean_wall, 1e-9),
        "clean_wall_s": clean_wall,
        "campaign_wall_s": campaign_wall,
        "streams_match_clean": campaign == clean,
        "replay_identical": replay_identical,
        "disabled_matches_clean": disabled_matches_clean,
        "protection_level_final": eng.protection_level,
        "protection_escalations": stats.protection_escalations,
        "protection_deescalations": stats.protection_deescalations,
        "escalation_trace": escalations,
        "schedule": fm.schedule,
        "injection_log": list(stats.injection_log),
    }
    return cell


def adaptive_quiet_check(model, params, cfg, *, slots, max_len,
                         requests, new_tokens, threshold) -> dict:
    """Quiet regime: the adaptive engine (fault model attached, rate 0)
    must match the base intensity-guided engine byte-for-byte — same
    streams, same per-layer plan rows, zero escalations."""
    def mk_reqs():
        return _requests(requests, new_tokens, cfg.vocab_size)
    base = _engine(model, params, slots=slots, max_len=max_len,
                   abft=_abft("intensity_guided"))
    base_out = base.run(mk_reqs())
    ada = _engine(model, params, slots=slots, max_len=max_len,
                  abft=_abft("adaptive", threshold=threshold),
                  fault_model=FaultModel(transient_rate=0.0, seed=0))
    ada_out = ada.run(mk_reqs())
    base_rows = [(r["layer"], r["scheme"]) for r in base.plan.report_rows()]
    ada_rows = [(r["layer"], r["scheme"]) for r in ada.plan.report_rows()]
    return {
        "streams_match": ada_out == base_out,
        "plan_rows_match": ada_rows == base_rows,
        "escalations": ada.stats.protection_escalations,
        "final_level": ada.protection_level,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--n-layers", type=int, default=2)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rates", default="0.3,0.15",
                    help="comma pair: transient rate, permanent rate")
    ap.add_argument("--escalate-threshold", type=float, default=0.02,
                    help="adaptive cells: detections-per-step rate that "
                         "triggers escalation (low, so the elevated "
                         "injected rate visibly escalates)")
    ap.add_argument("--quick", action="store_true",
                    help="2 cells (intensity_guided + adaptive, "
                         "transient only) — the CI smoke set")
    ap.add_argument("--out", default=None,
                    help="write JSON here (else stdout)")
    args = ap.parse_args(argv)

    cfg = scaled_down(get_config(args.arch), n_layers=args.n_layers)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)

    t_rate, p_rate = (float(r) for r in str(args.rates).split(","))
    rate_of = {"transient": t_rate, "permanent": p_rate}
    cells_todo = [(s, k) for s in SCHEMES for k in FAULT_KINDS]
    if args.quick:
        cells_todo = [("intensity_guided", "transient"),
                      ("adaptive", "transient")]

    cells = []
    for scheme, kind in cells_todo:
        cell = run_cell(
            model, params, cfg, scheme=scheme, kind=kind,
            rate=rate_of[kind], seed=args.seed, slots=args.slots,
            max_len=args.max_len, requests=args.requests,
            new_tokens=args.new_tokens,
            threshold=args.escalate_threshold)
        cells.append(cell)
        print(f"scheme={scheme:17s} kind={kind:9s} "
              f"injected={cell['faults_injected']:2d} "
              f"coverage={cell['coverage']:.2f} "
              f"sdc={cell['sdc_faults']} "
              f"overhead={cell['overhead']:.2f}x "
              f"esc={cell['protection_escalations']} "
              f"replay={cell['replay_identical']}")

    quiet = adaptive_quiet_check(
        model, params, cfg, slots=args.slots, max_len=args.max_len,
        requests=args.requests, new_tokens=args.new_tokens,
        threshold=args.escalate_threshold)
    print(f"adaptive quiet regime: streams_match={quiet['streams_match']} "
          f"plan_rows_match={quiet['plan_rows_match']} "
          f"escalations={quiet['escalations']}")

    summary = {
        "schema_version": 1,
        "arch": args.arch, "n_layers": args.n_layers,
        "slots": args.slots, "max_len": args.max_len,
        "requests": args.requests, "new_tokens": args.new_tokens,
        "seed": args.seed,
        "rates": rate_of,
        "escalate_threshold": args.escalate_threshold,
        "backend": jax.default_backend(),
        "cells": cells,
        "adaptive_quiet": quiet,
    }
    payload = json.dumps(summary, indent=2)
    if args.out:
        with open(args.out, "w") as fh:
            fh.write(payload)
        print(f"wrote {args.out}")
    else:
        print(payload)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
