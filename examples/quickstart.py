"""Quickstart: ABFT-protected matmuls in three lines, then a protected
model forward with fault injection + detection.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ABFTConfig,
    FaultSpec,
    GemmDims,
    Scheme,
    protected_matmul,
    select_scheme,
    selection_report,
)

# ---------------------------------------------------------------- 1. one GEMM
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((64, 512)), jnp.bfloat16)
w = jnp.asarray(rng.standard_normal((512, 1024)), jnp.bfloat16)

y, check = protected_matmul(x, w)          # scheme auto-selected by AI vs CMR
print(f"1) clean GEMM: fault detected = {bool(check.flag)}")

# inject a soft error into the GEMM output -> detected.  On the fused
# block path the bit indexes the f32 accumulator (bits 23-30 = exponent);
# on the global path it indexes the output dtype.
y, check = protected_matmul(x, w, fault=FaultSpec.bitflip(row=3, col=17,
                                                          bit=28))
print(f"   bit-flipped GEMM: fault detected = {bool(check.flag)}")
assert bool(check.flag)

# ---------------------------------------------------------------- 2. selection
print("\n2) intensity-guided selection (paper §5.3):")
report = selection_report({
    "decode mlp (thin)": GemmDims(m=8, k=4096, n=14336),
    "prefill mlp (fat)": GemmDims(m=131072, k=4096, n=14336),
})
for r in report:
    print(f"   {r['layer']:20s} AI={r['ai']:9.1f} {r['bound']:9s} "
          f"-> {r['scheme']}")

# ---------------------------------------------------------------- 3. a model
from repro.configs import get_config, scaled_down
from repro.models import LayerCtx, ModelFault, build_model

cfg = scaled_down(get_config("llama3.2-1b"))
model = build_model(cfg)
params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
ctx = LayerCtx(abft=ABFTConfig(scheme=Scheme.AUTO, use_pallas=False))
batch = {"tokens": jnp.ones((2, 16), jnp.int32)}

out = model.forward(params, batch, ctx)
print(f"\n3) model forward: logits {out.logits.shape}, "
      f"fault detected = {bool(out.flag)}")

bad_ctx = LayerCtx(
    abft=ctx.abft,
    fault=ModelFault.at(1, "mlp_down", FaultSpec.value(0, 3, 1e4)))
out = model.forward(params, batch, bad_ctx)
print(f"   with injected layer fault: detected = {bool(out.flag)}")
