"""Quickstart: ABFT-protected matmuls in three lines, then a protected
model forward with fault injection + detection.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ABFTConfig,
    FaultSpec,
    GemmDims,
    protected_matmul,
    selection_report,
)

# ---------------------------------------------------------------- 1. one GEMM
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((64, 512)), jnp.bfloat16)
w = jnp.asarray(rng.standard_normal((512, 1024)), jnp.bfloat16)

y, check = protected_matmul(x, w)          # scheme auto-selected by AI vs CMR
print(f"1) clean GEMM: fault detected = {bool(check.flag)}")

# inject a soft error into the GEMM output -> detected.  On the fused
# block path the bit indexes the f32 accumulator (bits 23-30 = exponent);
# on the global path it indexes the output dtype.
y, check = protected_matmul(x, w, fault=FaultSpec.bitflip(row=3, col=17,
                                                          bit=28))
print(f"   bit-flipped GEMM: fault detected = {bool(check.flag)}")
assert bool(check.flag)

# ---------------------------------------------------------------- 2. selection
print("\n2) intensity-guided selection (paper §5.3):")
report = selection_report({
    "decode mlp (thin)": GemmDims(m=8, k=4096, n=14336),
    "prefill mlp (fat)": GemmDims(m=131072, k=4096, n=14336),
})
for r in report:
    print(f"   {r['layer']:20s} AI={r['ai']:9.1f} {r['bound']:9s} "
          f"-> {r['scheme']}")

# ------------------------------------------------------- 2b. the policy API
# selection_report above rides the legacy facade; the first-class surface
# is a ProtectionPolicy compiled into a ProtectionPlan (JSON-serializable
# deployment artifact with a cached per-step fast path):
from repro.core import (
    IntensityGuidedPolicy,
    ProtectionPlan,
    StepShape,
    TPU_V5E,
)

plan = ProtectionPlan.build(
    {"decode mlp (thin)": GemmDims(m=8, k=4096, n=14336),
     "prefill mlp (fat)": GemmDims(m=131072, k=4096, n=14336)},
    hw=TPU_V5E, policy=IntensityGuidedPolicy(),
    step_shape=StepShape(d_model=4096, d_ff=14336))
reloaded = ProtectionPlan.from_json(plan.to_json())
assert [e.selection.scheme_name for e in reloaded.entries] == \
    [e.selection.scheme_name for e in plan.entries]
print(f"\n2b) plan round-trip: {len(plan.entries)} layers, "
      f"decode-step scheme = {plan.for_step(8).scheme_name}")

# ------------------------------------------------- 2c. the coverage auditor
# a plan *claims* protection; the auditor *proves* it: trace the model's
# real prefill/decode entry points to jaxprs, walk every FLOP-carrying
# primitive, and check each one sits inside a registered scheme's dispatch
# scope — with the plan <-> trace site bijection as a second witness.
# CLI equivalent: python -m repro.launch.audit --config llama3.2-1b
from repro.analysis import audit_config

rep = audit_config("llama3.2-1b", phase="decode", check_flash=False)
assert rep.protected_fraction == 1.0 and rep.crosscheck.bijective
print(f"\n2c) coverage audit: protected={rep.protected_fraction:.2f}; "
      f"{rep.crosscheck.report()}")

# ---------------------------------------------------- 2d. observability
# the serving telemetry stack is dependency-free and usable standalone:
# a metrics registry (JSON + Prometheus exposition), a Perfetto-JSON
# span tracer, and the rolling fault-rate monitor that feeds adaptive
# protection (ROADMAP 5b).  The serve driver wires all three behind
# --metrics-out / --trace-out / --log-events.
from repro.obs import FaultRateMonitor, MetricsRegistry, Tracer

reg = MetricsRegistry()
detections = reg.counter("abft_faults_detected_total",
                         "ABFT checksum mismatches", labels=("scheme",))
detections.labels(scheme="global").inc()
lat = reg.histogram("serve_step_latency_seconds", "step wall time",
                    buckets=(0.001, 0.01, 0.1))
lat.observe(0.004)

tracer = Tracer()
with tracer.span("decode_step", {"tokens": 8}):
    with tracer.span("abft_check"):
        pass
tracer.instant("scheme_flip", {"scheme": "global", "intensity": 42.0})

monitor = FaultRateMonitor(window=128)
monitor.observe(steps=1, tokens=8, detections=1, retries=1)
print("\n2d) telemetry:")
print("   " + "\n   ".join(reg.render_prometheus().splitlines()[:4]))
print(f"   trace events = {len(tracer.events)}, windowed detection "
      f"rate = {monitor.window_detection_rate:.3f}/step")

# ------------------------------------------- 2e. per-shard plans (mesh)
# tensor parallelism divides each GEMM's N (column-parallel) or K
# (row-parallel) by the mesh width, lowering every shard's arithmetic
# intensity — so the same layer on the same hardware can land on a
# DIFFERENT scheme once sharded.  Plan compilation is host-side: no
# devices needed to see the divergence (serving over a real mesh is
# ServeEngine(mesh=k); see README "Sharded serving").
from repro.configs import get_config, scaled_down
from repro.core.hardware import HardwareSpec
from repro.models import LayerCtx, ModelFault, build_model

cfg = scaled_down(get_config("llama3.2-1b"))
model = build_model(cfg)
shard_hw = HardwareSpec(        # CMR between full-width and 4-way-shard AI
    name="shard-flip", peak_flops=2.4e13, vpu_flops=1e11, hbm_bw=1e12,
    ici_bw=1e11, hbm_bytes=1 << 34, vmem_bytes=1 << 24,
    fixed_op_overhead_s=1e-7)
print("\n2e) per-shard protection plans (tensor parallel):")
per_width = {}
for tp in (1, 4):
    p = model.protection_plan(hw=shard_hw, phase="serve", n_tokens=64,
                              model_parallel=tp)
    per_width[tp] = {r["layer"]: r for r in p.report_rows()}
for layer, row in per_width[1].items():
    r4 = per_width[4][layer]
    mark = "  <- scheme flips" if row["scheme"] != r4["scheme"] else ""
    print(f"   {layer:9s} TP=1 ai={row['ai']:5.1f} {row['scheme']:8s} | "
          f"TP=4 ai={r4['ai']:5.1f} {r4['scheme']:8s}{mark}")
assert any(per_width[1][la]["scheme"] != per_width[4][la]["scheme"]
           for la in per_width[1])

# ------------------------------- 2f. fault campaigns + adaptive protection
# the one-shot fault above becomes a *process*: a seeded FaultModel
# Bernoulli-injects transient (or sticky permanent) faults every engine
# step, the engine's shadow-stream harness classifies each one as
# corrected / uncorrected / SDC / masked, and an ErrorAdaptivePolicy
# consumes the observed fault RATE to escalate protection at runtime
# (ROADMAP 5b/5c; benchmarks/fault_campaign.py runs the full sweep).
from repro.core import ErrorAdaptivePolicy, FaultModel, Scheme
from repro.serve.engine import Request, ServeEngine

print("\n2f) fault campaign + error-rate-adaptive escalation:")
qparams = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
qreqs = lambda: [Request(uid=i,                                 # noqa: E731
                         prompt=np.arange(1, 6 + i, dtype=np.int32),
                         max_new_tokens=5) for i in range(2)]
adaptive = ErrorAdaptivePolicy(IntensityGuidedPolicy(),
                               detection_threshold=0.05)
campaign = FaultModel(transient_rate=0.5, seed=1, layers=cfg.n_layers,
                      dtype=jnp.float32, magnitude=1e4)
clean_eng = ServeEngine(model, qparams, slots=2, max_len=64,
                        abft=ABFTConfig.from_policy(
                            IntensityGuidedPolicy(), use_pallas=False),
                        dtype=jnp.float32)
clean_streams = clean_eng.run(qreqs())
eng = ServeEngine(model, qparams, slots=2, max_len=64,
                  abft=ABFTConfig.from_policy(adaptive,
                                              use_pallas=False),
                  dtype=jnp.float32, fault_model=campaign)
streams = eng.run(qreqs())
s = eng.stats
print(f"   injected={s.faults_injected} corrected={s.faults_corrected} "
      f"uncorrected={s.faults_uncorrected} sdc={s.sdc_faults} "
      f"masked={s.masked_faults}")
print(f"   escalations={s.protection_escalations} "
      f"(level {eng.protection_level}: the observed detection rate "
      f"crossed {adaptive.detection_threshold})")
for entry in s.injection_log[:3]:
    print(f"   step {entry['engine_step']:2d} {entry['phase']:8s} "
          f"L{entry['layer']} {entry['site']:8s} -> {entry['outcome']}")
assert s.faults_injected > 0 and s.sdc_faults == 0
assert s.protection_escalations >= 1
assert streams == clean_streams          # recovery stayed transparent

# ----------------------------- 2g. speculative decoding flips the scheme
# spec_decode speculates K drafts per slot and scores all K+1 positions
# in ONE jitted verify call — so a decode step's token dimension grows
# from `slots` to sum(k_i + 1).  On hardware whose scheme crossover sits
# between the two (here ~18 tokens for this f32 plan: 4-slot plain
# decode = 4 tokens, full K=4 verify window = 20), speculation alone
# flips the per-step scheme — the paper's intensity decision reacting
# to the serving optimization.  Streams stay byte-identical: greedy
# verify provably reproduces the unsped stream (see
# repro/serve/spec_decode.py), so draft quality only buys throughput.
flip_hw = HardwareSpec(
    name="flip", peak_flops=1e10, vpu_flops=2.6e8, hbm_bw=1e9,
    ici_bw=1e9, hbm_bytes=1 << 30, vmem_bytes=1 << 20,
    fixed_op_overhead_s=1e-6)
spec_reqs = lambda: [Request(uid=i,                             # noqa: E731
                             prompt=np.tile(np.arange(3, 7 + i % 2,
                                                      dtype=np.int32),
                                            16)[:21 + 2 * i],
                             max_new_tokens=14 + i % 3)
                     for i in range(4)]
spec_abft = ABFTConfig(scheme=Scheme.AUTO, use_pallas=False,
                       hardware=flip_hw)
print("\n2g) speculative decoding (K-sweep on scheme-flip hardware):")
base_eng = ServeEngine(model, qparams, slots=4, max_len=64,
                       abft=spec_abft, dtype=jnp.float32)
base = base_eng.run(spec_reqs())
for k in (1, 4):
    seng = ServeEngine(model, qparams, slots=4, max_len=64,
                       abft=spec_abft, dtype=jnp.float32,
                       spec_decode="ngram", draft_len=k)
    sout = seng.run(spec_reqs())
    assert sout == base                  # byte-identical greedy streams
    st = seng.stats
    schemes = sorted({e["scheme"] for e in st.selection_trace
                      if e["decode"] and not e["prefill"]})
    rate = st.draft_accepted / max(st.draft_proposed, 1)
    print(f"   K={k}: accept={rate:.2f} verify-window schemes={schemes}")
    if k == 4:
        assert "global" in schemes       # K=4 window crossed the CMR
print(f"   plan.for_step:  4 tokens -> "
      f"{base_eng.plan.for_step(4).scheme_name},  20 tokens -> "
      f"{base_eng.plan.for_step(20).scheme_name}")

# ---------------------------------------------------------------- 3. a model
params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
ctx = LayerCtx(abft=ABFTConfig.from_policy(IntensityGuidedPolicy(),
                                           use_pallas=False))
batch = {"tokens": jnp.ones((2, 16), jnp.int32)}

out = model.forward(params, batch, ctx)
print(f"\n3) model forward: logits {out.logits.shape}, "
      f"fault detected = {bool(out.flag)}")

bad_ctx = LayerCtx(
    abft=ctx.abft,
    fault=ModelFault.at(1, "mlp_down", FaultSpec.value(0, 3, 1e4)))
out = model.forward(params, batch, bad_ctx)
print(f"   with injected layer fault: detected = {bool(out.flag)}")
