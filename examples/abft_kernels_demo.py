"""Kernel-level demo: the two fused-ABFT Pallas kernels.

1. abft_matmul — the paper's block-level (thread-level-equivalent) scheme:
   checksums computed on VMEM-resident tiles, zero extra HBM traffic,
   per-row fault location.
2. flash_attention — beyond-paper: ABFT fused into both attention GEMMs,
   with the checksum invariant carried through the online-softmax
   rescaling.

  PYTHONPATH=src python examples/abft_kernels_demo.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import FaultSpec
from repro.kernels import abft_matmul, flash_attention

rng = np.random.default_rng(0)

# ---- 1. fused-ABFT matmul ------------------------------------------------
x = jnp.asarray(rng.standard_normal((256, 512)), jnp.float32)
w = jnp.asarray(rng.standard_normal((512, 256)), jnp.float32)

y, chk = abft_matmul(x, w, mode="1s", out_dtype=jnp.float32)
print(f"matmul clean:     flag={bool(chk.flag)}  "
      f"max residual/threshold="
      f"{float(jnp.max(chk.residual / chk.threshold)):.2e}")

y, chk = abft_matmul(x, w, mode="1s", out_dtype=jnp.float32,
                     fault=FaultSpec.bitflip(row=100, col=42, bit=28))
res = np.asarray(chk.residual)      # (gm, gn, bm): locates the faulty row
gm, gn, bm = res.shape
hot = np.unravel_index(np.argmax(res), res.shape)
print(f"matmul bit-flip:  flag={bool(chk.flag)}  "
      f"located row={hot[0] * bm + hot[2]} (injected row=100)")

# ---- 2. fused-ABFT flash attention ----------------------------------------
q = jnp.asarray(rng.standard_normal((1, 128, 4, 32)), jnp.float32)
k = jnp.asarray(rng.standard_normal((1, 128, 4, 32)), jnp.float32)
v = jnp.asarray(rng.standard_normal((1, 128, 4, 32)), jnp.float32)

o, chk = flash_attention(q, k, v, causal=True, bq=32, bk=32)
print(f"attention clean:  flag={bool(chk.flag)}  out={o.shape}")

o, chk = flash_attention(q, k, v, causal=True, bq=32, bk=32,
                         fault=FaultSpec.value(row=7, col=3, delta=40.0))
print(f"attention fault:  flag={bool(chk.flag)} "
      "(detected through the online-softmax rescaling)")
assert bool(chk.flag)
print("OK")
