"""Serving example: continuous-batched generation with a soft-error
campaign — faults are injected mid-decode, detected by ABFT, and recovered
by recompute; the output stream is verified identical to a clean run.

The requests have *different prompt lengths* and share two slots: the
engine's vectorized per-slot cursor keeps every request's KV rows isolated
(mixed-length batching was silently corrupted by the seed's scalar-pos
engine), and each recovered stream also matches the request served alone.

  PYTHONPATH=src python examples/serve_with_faults.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, scaled_down
from repro.core import ABFTConfig, FaultSpec, Scheme
from repro.models import ModelFault, build_model
from repro.serve.engine import RecoveryPolicy, Request, ServeEngine

cfg = scaled_down(get_config("qwen3-14b"))
model = build_model(cfg)
params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float32)
abft = ABFTConfig(scheme=Scheme.AUTO, use_pallas=False)
policy = RecoveryPolicy(max_retries=1, evict_on_hard_fault=True)


def make_requests():
    return [
        Request(uid=i, prompt=np.arange(1, 9 + i, dtype=np.int32),
                max_new_tokens=6)
        for i in range(3)
    ]


def make_engine():
    return ServeEngine(model, params, slots=2, max_len=64, abft=abft,
                       dtype=jnp.float32, policy=policy)


# clean run
clean = make_engine().run(make_requests())

# each request served alone must match its continuous-batched stream
for ref in make_requests():
    solo = make_engine().run([ref])
    assert solo[ref.uid] == clean[ref.uid], (
        f"mixed-length batching diverged from solo decode for {ref.uid}")

# faulty run: corrupt layer 1's attention output GEMM at decode step 2
fault = ModelFault.at(1, "attn_out", FaultSpec.value(0, 5, 5e4))
eng = make_engine()
faulty = eng.run(make_requests(), fault_at=(2, fault))

print(f"requests served:   {len(faulty)}")
print(f"faults detected:   {eng.stats.faults_detected}")
print(f"retries:           {eng.stats.retries}")
print(f"hard faults:       {eng.stats.hard_faults}")
match = all(clean[k] == faulty[k] for k in clean)
print(f"recovered outputs match clean run: {match}")
assert match and eng.stats.faults_detected >= 1
print("OK: soft error detected by ABFT and recovered transparently, "
      "with per-slot cursors keeping mixed-length requests isolated.")
