"""End-to-end training example: a ~100M-param llama-family model trained
for a few hundred steps with the full production stack — ABFT-protected
forward, AdamW, checkpointing, deterministic data, detect->retry recovery.

CPU demo (fast):
  PYTHONPATH=src python examples/train_lm.py

Real scale (TPU, a few hundred steps of the ~100M config per deliverable b):
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --scale 100m --steps 300 --batch 32 --seq 1024 --abft auto
"""

import sys

from repro.launch.train import main

if __name__ == "__main__":
    # CPU-sized invocation of the same production driver; pass your own
    # flags to override (e.g. --scale 100m --steps 300 on accelerators).
    argv = sys.argv[1:] or [
        "--arch", "llama3.2-1b", "--scale", "smoke",
        "--steps", "30", "--batch", "4", "--seq", "64",
        "--lr", "3e-3", "--abft", "auto", "--ckpt-every", "10",
    ]
    raise SystemExit(main(argv))
