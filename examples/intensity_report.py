"""Pre-deployment intensity report (paper §5.3 'integration with
pre-deployment optimizers'): compile the architecture's ProtectionPlan
for a serving shape and print, per GEMM site, the arithmetic intensity,
the bound regime, and the scheme the ProtectionPolicy selected — plus
the roofline-autotuned chunked-prefill budget for the device.

  PYTHONPATH=src python examples/intensity_report.py [arch] [n_tokens]
      [--scale smoke] [--plan-out plan.json]

The plan can be dumped as the JSON deployment artifact with --plan-out;
reloading it (ProtectionPlan.from_json) reproduces identical per-step
selections.
"""

import argparse

from repro.configs import ALL_ARCHS, get_config, scaled_down
from repro.core import TPU_V5E, IntensityGuidedPolicy, ProtectionPlan
from repro.models.counting import aggregate_ai

ap = argparse.ArgumentParser()
ap.add_argument("arch", nargs="?", default="deepseek-v3-671b",
                choices=ALL_ARCHS)
ap.add_argument("n_tokens", nargs="?", type=int, default=128,
                help="tokens per serving step (decode batch)")
ap.add_argument("--scale", choices=["full", "smoke"], default="full",
                help="smoke: scaled-down config (CI examples job)")
ap.add_argument("--plan-out", default=None,
                help="write the compiled ProtectionPlan JSON here")
args = ap.parse_args()

cfg = get_config(args.arch)
if args.scale == "smoke":
    cfg = scaled_down(cfg)

plan = ProtectionPlan.for_model(
    cfg, hw=TPU_V5E, policy=IntensityGuidedPolicy(),
    phase="serve", n_tokens=args.n_tokens)

print(f"arch={args.arch} ({plan.model})  tokens-per-step={args.n_tokens}  "
      f"device={plan.hardware.name} (CMR={plan.hardware.cmr:.0f})")
print(f"aggregate AI: {aggregate_ai(cfg, args.n_tokens):.1f}")
budget = plan.tune_chunk_budget(lo=8, hi=32768)
print(f"auto chunk budget: {budget} tokens "
      f"(mixed-step AI {plan.step_intensity(budget):.1f})\n")
print(f"{'site':18s} {'m':>9s} {'k':>7s} {'n':>7s} {'count':>6s} "
      f"{'AI':>9s} {'bound':>10s} {'first':>6s}  scheme")
for row in plan.report_rows():
    print(f"{row['layer']:18s} {row['m']:>9d} {row['k']:>7d} "
          f"{row['n']:>7d} {row['count']:>6d} {row['ai']:>9.1f} "
          f"{row['bound']:>10s} {str(row['first']):>6s}  {row['scheme']}")

if args.plan_out:
    with open(args.plan_out, "w") as fh:
        fh.write(plan.to_json())
    print(f"\nwrote plan artifact -> {args.plan_out}")

print("\n(available archs: " + ", ".join(ALL_ARCHS) + ")")
