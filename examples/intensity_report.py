"""Pre-deployment intensity report (paper §5.3 'integration with
pre-deployment optimizers'): for any assigned architecture and serving
shape, print the per-GEMM-site arithmetic intensity, the bound regime, and
the ABFT scheme intensity-guided selection chooses.

  PYTHONPATH=src python examples/intensity_report.py [arch] [n_tokens]
"""

import sys

from repro.configs import ALL_ARCHS, get_config
from repro.core import TPU_V5E, select_scheme
from repro.models.counting import aggregate_ai, layer_gemms

arch = sys.argv[1] if len(sys.argv) > 1 else "deepseek-v3-671b"
n_tokens = int(sys.argv[2]) if len(sys.argv) > 2 else 128  # decode batch

cfg = get_config(arch)
print(f"arch={arch}  tokens-per-step={n_tokens}  "
      f"device={TPU_V5E.name} (CMR={TPU_V5E.cmr:.0f})")
print(f"aggregate AI: {aggregate_ai(cfg, n_tokens):.1f}\n")
print(f"{'site':18s} {'m':>9s} {'k':>7s} {'n':>7s} {'count':>6s} "
      f"{'AI':>9s} {'bound':>10s}  scheme")
for site, (dims, count) in layer_gemms(cfg, n_tokens).items():
    sel = select_scheme(dims, TPU_V5E)
    bound = "compute" if dims.arithmetic_intensity >= TPU_V5E.cmr \
        else "bandwidth"
    print(f"{site:18s} {dims.m:>9d} {dims.k:>7d} {dims.n:>7d} {count:>6d} "
          f"{dims.arithmetic_intensity:>9.1f} {bound:>10s}  "
          f"{sel.scheme.value}")
print("\n(available archs: " + ", ".join(ALL_ARCHS) + ")")
